//! `proxystore` launcher: run servers, demos, applications, and the
//! paper's experiments from one binary.

use std::time::Duration;

use proxystore::apps::{ddmd, genomes, membench, mof, streambench};
use proxystore::benchlib::fmt_secs;
use proxystore::cli::Args;
use proxystore::error::{Error, Result};
use proxystore::ownership::StoreOwnedExt;
use proxystore::prelude::{Proxy, ProxyFuture, Store};
use proxystore::codec::Encode;
use proxystore::runtime::{default_artifacts_dir, ModelRegistry};
use proxystore::workflow::DataMode;

const HELP: &str = "\
proxystore — object proxy patterns for distributed applications

USAGE: proxystore <COMMAND> [OPTIONS]

COMMANDS:
  quickstart                    minimal proxy / future / ownership demo
  fig5     [--f 0.2] [--tasks 8] [--task-ms 300] [--size 10000000]
                                task pipelining (paper Fig 5)
  fig6     [--workers 8] [--size 1000000] [--items 50] [--brokers 1]
                                stream processing (paper Fig 6); --brokers >1
                                runs the partitioned broker fabric
  fig7     [--rounds 4] [--mappers 8]
                                memory management (paper Fig 7)
  genomes  [--mode noproxy|proxy|proxyfuture] [--individuals 64]
                                1000 Genomes workflow (paper Fig 8)
  ddmd     [--mode baseline|stream] [--rounds 10]
                                DeepDriveMD inference (paper Fig 9)
  mof      [--mode default|ownership] [--rounds 6]
                                MOF generation (paper Fig 10)
  shard    [--shards 4] [--replicas 2] [--keys 64] [--size 262144]
                                sharded store fabric demo: consistent-hash
                                routing, batched MGET/MPUT, replica failover
  rebalance [--shards 4] [--keys 256] [--size 65536] [--replicas 1]
                                elastic shard fabric demo: live add/remove
                                shard with read-through migration under
                                concurrent load, zero lost reads
  broker-shard [--instances 4] [--partitions 8] [--events 256] [--size 16384]
                                partitioned broker fabric demo: topic
                                partitions spread over N instances, batched
                                produce/fetch, group fan-in, failure injection
  stats    [--shards 2] [--keys 64] [--size 4096]
                                telemetry plane demo: traced ops over a live
                                TCP sharded fabric, registry snapshot fetched
                                over the wire and rendered
  obs      [--shards 4] [--keys 64] [--size 4096] [--trace-out results/obs.trace.json]
                                observability plane demo: HTTP admin endpoint
                                scraped live, merged multi-node snapshot,
                                cross-process span trees, Chrome trace JSON
                                export, slow-op log
  persist  [--keys 128] [--size 4096] [--data-dir <path>]
                                durability plane demo: durable KV shard and
                                broker, hard kill, same-port restart, WAL +
                                snapshot recovery verified, data-dir listing
  serve-kv                      run a redis-sim KV server (ephemeral port,
                                HTTP admin plane on a second port)
  serve-broker                  run a log-broker server (ephemeral port,
                                HTTP admin plane on a second port)
  version                       print the crate version

Artifacts are read from ./artifacts (override: PROXYSTORE_ARTIFACTS).
Run `make artifacts` first for commands that execute compiled models
(ddmd, mof).";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        None | Some("help") => {
            println!("{HELP}");
            Ok(())
        }
        Some("version") => {
            println!("proxystore {}", proxystore::version());
            Ok(())
        }
        Some("quickstart") => quickstart(),
        Some("fig5") => fig5(args),
        Some("fig6") => fig6(args),
        Some("fig7") => fig7(args),
        Some("genomes") => genomes_cmd(args),
        Some("ddmd") => ddmd_cmd(args),
        Some("mof") => mof_cmd(args),
        Some("shard") => shard_cmd(args),
        Some("rebalance") => rebalance_cmd(args),
        Some("broker-shard") => broker_shard_cmd(args),
        Some("stats") => stats_cmd(args),
        Some("obs") => obs_cmd(args),
        Some("persist") => persist_cmd(args),
        Some("serve-kv") => serve_kv(),
        Some("serve-broker") => serve_broker(),
        Some(other) => Err(Error::Config(format!(
            "unknown command {other:?}; see `proxystore help`"
        ))),
    }
}

fn quickstart() -> Result<()> {
    println!("# proxies");
    let store = Store::memory("quickstart");
    let proxy: Proxy<String> = store.proxy(&"hello proxy".to_string())?;
    println!("created {proxy:?} ({} wire bytes)", proxy.to_bytes().len());
    println!("resolved: {}", proxy.resolve()?);

    println!("\n# distributed futures");
    let fut: ProxyFuture<u64> = store.future();
    let p = fut.proxy();
    let consumer = std::thread::spawn(move || *p.resolve().unwrap());
    std::thread::sleep(Duration::from_millis(50));
    fut.set_result(&42)?;
    println!("consumer observed: {}", consumer.join().unwrap());

    println!("\n# zero-copy views");
    // `get::<T>` decodes an owned object; `get_view` hands back a `Buf`
    // window over the channel's own allocation — the serialized bytes
    // without a copy. Clones of the view are refcount bumps.
    let key = store.put(&vec![7u8; 1 << 20])?;
    let view = store.get_view(&key)?.expect("just stored");
    let again = view.clone();
    println!(
        "viewed {} serialized bytes twice, zero copies ({} == {})",
        view.len(),
        view.as_ptr() as usize,
        again.as_ptr() as usize,
    );

    println!("\n# ownership");
    let owned = store.owned_proxy(&"owned".to_string())?;
    let key = owned.key().to_string();
    let borrow = proxystore::ownership::borrow(&owned)?;
    println!("borrowed read: {}", borrow.resolve()?);
    drop(borrow);
    drop(owned);
    println!("evicted after owner drop: {}", !store.exists(&key)?);
    Ok(())
}

fn fig5(args: &Args) -> Result<()> {
    let n: usize = args.get_parse("tasks", 8)?;
    let task_ms: u64 = args.get_parse("task-ms", 300)?;
    let d: usize = args.get_parse("size", 10_000_000)?;
    let f: f64 = args.get_parse("f", 0.2)?;
    let s = Duration::from_millis(task_ms);
    println!("fig5: n={n} s={task_ms}ms d={d}B f={f}");
    for mode in [DataMode::NoProxy, DataMode::Proxy, DataMode::ProxyFuture] {
        let chain = proxystore::workflow::synthetic_chain(n, s, f, d);
        let cluster = proxystore::workflow::cluster_for(
            n,
            proxystore::engine::ClusterConfig {
                submit_overhead: Duration::from_millis(5),
                ..Default::default()
            },
        );
        let store = Store::memory("fig5");
        let report = chain.run(&cluster, &store, mode)?;
        println!("\n[{}] makespan = {}", mode.label(), fmt_secs(report.makespan));
        println!("{}", report.timeline.ascii_gantt(72));
    }
    Ok(())
}

fn fig6(args: &Args) -> Result<()> {
    let cfg = streambench::StreamBenchConfig {
        workers: args.get_parse("workers", 8)?,
        data_size: args.get_parse("size", 1_000_000)?,
        items: args.get_parse("items", 50)?,
        task_time: Duration::from_millis(args.get_parse("task-ms", 200)?),
        dispatcher_bw: args.get_parse("dispatcher-bw", 1.0e8)?,
        broker_instances: args.get_parse("brokers", 1)?,
        seed: args.get_parse("seed", 6)?,
    };
    println!("fig6: {cfg:?}");
    for mode in streambench::StreamMode::all() {
        let r = streambench::run(&cfg, mode)?;
        println!(
            "[{}] {:.1} tasks/s over {} ({} items)",
            mode.label(),
            r.tasks_per_sec,
            fmt_secs(r.elapsed),
            r.items
        );
    }
    Ok(())
}

fn fig7(args: &Args) -> Result<()> {
    let cfg = membench::MemBenchConfig {
        rounds: args.get_parse("rounds", 4)?,
        mappers: args.get_parse("mappers", 8)?,
        map_input: args.get_parse("map-input", 10_000_000)?,
        map_output: args.get_parse("map-output", 1_000_000)?,
        task_sleep: Duration::from_millis(args.get_parse("sleep-ms", 200)?),
        seed: 7,
    };
    println!("fig7: {cfg:?}");
    for mode in membench::MemMode::all() {
        let r = membench::run(&cfg, mode)?;
        println!(
            "[{}] peak store = {:.1} MB, final = {:.1} MB, makespan = {}",
            mode.label(),
            r.series.peak_store() as f64 / 1e6,
            r.series.final_store() as f64 / 1e6,
            fmt_secs(r.makespan)
        );
    }
    Ok(())
}

fn genomes_cmd(args: &Args) -> Result<()> {
    let cfg = genomes::GenomesConfig {
        individuals: args.get_parse("individuals", 64)?,
        chunks: args.get_parse("chunks", 8)?,
        snps_per_chunk: args.get_parse("snps", 2000)?,
        ..Default::default()
    };
    let mode = match args.get("mode").unwrap_or("proxyfuture") {
        "noproxy" => DataMode::NoProxy,
        "proxy" => DataMode::Proxy,
        "proxyfuture" => DataMode::ProxyFuture,
        other => return Err(Error::Config(format!("unknown mode {other}"))),
    };
    println!("genomes: mode={} {cfg:?}", mode.label());
    let (report, freq) = genomes::run(&cfg, mode)?;
    println!("makespan = {}", fmt_secs(report.makespan));
    println!("overlapping variants found: {}", freq.len());
    println!("{}", report.timeline.ascii_gantt(72));
    Ok(())
}

fn ddmd_cmd(args: &Args) -> Result<()> {
    let reg = ModelRegistry::load(default_artifacts_dir())?;
    let cfg = ddmd::DdmdConfig {
        rounds: args.get_parse("rounds", 10)?,
        ..Default::default()
    };
    match args.get("mode").unwrap_or("stream") {
        "baseline" => {
            let r = ddmd::run_baseline(&cfg, &reg)?;
            println!("baseline mean RTT = {}", fmt_secs(r.mean_rtt));
        }
        "stream" => {
            let r = ddmd::run_proxystream(&cfg, &reg)?;
            println!(
                "proxystream mean RTT = {} ({} model updates)",
                fmt_secs(r.mean_rtt),
                r.model_updates
            );
        }
        other => return Err(Error::Config(format!("unknown mode {other}"))),
    }
    Ok(())
}

fn mof_cmd(args: &Args) -> Result<()> {
    let reg = ModelRegistry::load(default_artifacts_dir())?;
    let cfg = mof::MofConfig {
        rounds: args.get_parse("rounds", 6)?,
        generators: args.get_parse("generators", 3)?,
        ..Default::default()
    };
    let mode = match args.get("mode").unwrap_or("ownership") {
        "default" => mof::MemoryMode::Default,
        "ownership" => mof::MemoryMode::Ownership,
        other => return Err(Error::Config(format!("unknown mode {other}"))),
    };
    let r = mof::run(&cfg, &reg, mode)?;
    println!(
        "[{}] best score = {:.4}, peak active proxies = {}, final = {}",
        mode.label(),
        r.best_score,
        r.series.peak_active(),
        r.series.final_active()
    );
    Ok(())
}

fn shard_cmd(args: &Args) -> Result<()> {
    use proxystore::codec::{Bytes, Decode};
    use proxystore::shard::ShardedConnector;
    use proxystore::store::{Connector, MemoryConnector, ThrottledConnector};
    use proxystore::testing::fail::FlakyConnector;
    use std::sync::Arc;

    let shards: usize = args.get_parse("shards", 4)?;
    let replicas: usize = args.get_parse("replicas", 2)?;
    let n_keys: usize = args.get_parse("keys", 64)?;
    let size: usize = args.get_parse("size", 256 * 1024)?;
    println!("shard: shards={shards} replicas={replicas} keys={n_keys} size={size}B");

    // Each backend is a memory channel behind a throttled link, so the
    // single-endpoint bottleneck the fabric removes is actually present.
    let throttled = |_: usize| {
        ThrottledConnector::wrap(
            MemoryConnector::new(),
            Duration::from_micros(200),
            2.0e8,
        )
    };
    let objs: Vec<Bytes> = (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();

    println!("\n# batched throughput: 1 shard vs {shards} shards");
    let mut baseline = 0.0;
    for n in [1, shards] {
        let fabric = Arc::new(ShardedConnector::new(
            (0..n).map(throttled).collect(),
            1,
            0,
        )?);
        let store = Store::new("fabric", fabric);
        let t0 = std::time::Instant::now();
        let keys = store.put_many(&objs)?;
        let put_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let got: Vec<Option<Bytes>> = store.get_many(&keys)?;
        let get_s = t0.elapsed().as_secs_f64();
        assert!(got.iter().all(|b| b.is_some()));
        let mb = (n_keys * size) as f64 / 1e6;
        if n == 1 {
            baseline = get_s;
        }
        println!(
            "  [{n} shard{}] mput {:.1} MB/s, mget {:.1} MB/s{}",
            if n == 1 { "" } else { "s" },
            mb / put_s,
            mb / get_s,
            if n == 1 {
                String::new()
            } else {
                format!(" ({:.1}x get speedup)", baseline / get_s)
            },
        );
    }

    // Replication below 2 cannot survive a backend death; report the
    // effective factor actually used rather than silently upgrading.
    let failover_replicas = replicas.max(2).min(shards);
    let flaky: Vec<Arc<FlakyConnector>> = (0..shards)
        .map(|i| FlakyConnector::wrap(throttled(i)))
        .collect();
    let fabric = Arc::new(ShardedConnector::new(
        flaky
            .iter()
            .map(|f| f.clone() as Arc<dyn Connector>)
            .collect(),
        failover_replicas,
        0,
    )?);
    let store = Store::new("failover", fabric.clone());
    let keys = store.put_many(&objs)?;
    if shards >= 2 {
        println!(
            "\n# failover: effective replicas={failover_replicas}\
             {}, killing one backend",
            if failover_replicas != replicas {
                format!(" (requested {replicas})")
            } else {
                String::new()
            },
        );
        flaky[0].set_down(true);
        let got: Vec<Option<Bytes>> = store.get_many(&keys)?;
        let alive = got.iter().filter(|b| b.is_some()).count();
        println!(
            "  backend 0 down: {alive}/{n_keys} objects still readable \
             ({} replica-fallback reads)",
            fabric.fallback_reads()
        );
        flaky[0].set_down(false);
    } else {
        println!("\n# failover: skipped (needs --shards >= 2)");
    }

    println!("\n# self-contained sharded proxies");
    let proxy: Proxy<Bytes> = store.proxy(&objs[0])?;
    let wire = proxy.to_bytes();
    let shipped: Proxy<Bytes> = Proxy::from_bytes(&wire)?;
    println!(
        "  proxy of a {size}B object serializes to {}B (embeds the whole \
         {shards}-shard layout) and resolves to {}B",
        wire.len(),
        shipped.resolve()?.0.len()
    );
    Ok(())
}

fn rebalance_cmd(args: &Args) -> Result<()> {
    use proxystore::codec::{Bytes, Decode};
    use proxystore::net::ServerBuilder;
    use proxystore::metrics::telemetry;
    use proxystore::shard::{ElasticShards, ShardMembers};
    use proxystore::store::{Connector, TcpKvConnector};
    use proxystore::testing::load::ReadProbe;
    use std::sync::Arc;

    let shards: usize = args.get_parse("shards", 4)?;
    let replicas: usize = args.get_parse("replicas", 1)?;
    let n_keys: usize = args.get_parse("keys", 256)?;
    let size: usize = args.get_parse("size", 64 * 1024)?;
    println!(
        "rebalance: shards={shards} replicas={replicas} keys={n_keys} \
         size={size}B"
    );

    // Real TCP KV servers as backends: migration pays actual wire time,
    // and the telemetry plane below sees both halves of every op (client
    // spans, server frames, migration fan-outs on the reactor pool).
    let mut servers = Vec::new();
    let mut backend = || -> Result<Arc<dyn Connector>> {
        let server = ServerBuilder::new().spawn_kv()?;
        let conn =
            Arc::new(TcpKvConnector::connect(server.addr)?) as Arc<dyn Connector>;
        servers.push(server);
        Ok(conn)
    };
    let mut members: ShardMembers = Vec::with_capacity(shards);
    for id in 0..shards {
        members.push((id, backend()?));
    }
    let elastic = ElasticShards::new("rebalance-demo", members, replicas, 0)?;
    let store = Store::new("elastic", Arc::new(elastic.clone()));

    // Trace the driver thread's ops so the snapshot ends with a span tree.
    let _trace = telemetry::start_trace("rebalance-demo");

    let objs: Vec<Bytes> =
        (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();
    let keys = store.put_many(&objs)?;
    println!("stored {n_keys} objects across {shards} shards");

    // A proxy minted NOW must survive every rebalance below.
    let early_proxy: Proxy<Bytes> = store.proxy(&objs[0])?;
    let early_wire = early_proxy.to_bytes();

    // Arm a watch on a key that does not exist yet; both membership
    // changes below must re-arm it, and the late put must still wake it.
    let sentinel = "rebalance-sentinel";
    let armed = store.watch_async::<Bytes>(sentinel);

    // Concurrent readers hammer the full key set while shards come and go;
    // every get must hit.
    let probe = ReadProbe::spawn(&store, &keys, 2);

    println!("\n# scale-out: adding shard {shards} under load");
    let t0 = std::time::Instant::now();
    let new_backend = backend()?;
    elastic.add_shard(shards, new_backend)?;
    elastic.wait_quiescent(None);
    let grow = elastic.metrics();
    println!(
        "  migrated {}/{n_keys} keys ({:.1}%, ideal {:.1}%) in {}, {} moved, \
         {} dual reads ({} served by old placement)",
        grow.keys_migrated,
        100.0 * grow.keys_migrated as f64 / n_keys as f64,
        100.0 / (shards + 1) as f64,
        fmt_secs(t0.elapsed().as_secs_f64()),
        proxystore::benchlib::fmt_bytes(grow.bytes_moved as usize),
        grow.dual_reads,
        grow.dual_read_hits,
    );

    println!("\n# scale-in: removing shard 0 under load");
    let t0 = std::time::Instant::now();
    elastic.remove_shard(0)?;
    elastic.wait_quiescent(None);
    let shrink = elastic.metrics();
    println!(
        "  migrated {} keys in {}, fabric now {:?} (generation {})",
        shrink.keys_migrated - grow.keys_migrated,
        fmt_secs(t0.elapsed().as_secs_f64()),
        elastic.shard_ids(),
        elastic.generation(),
    );

    let (reads, misses) = probe.finish();
    println!("\n# read availability: {reads} concurrent reads, {misses} misses");

    // Fulfil the sentinel: the watch armed before both rebalances (and
    // re-armed across each epoch flip) completes from this put's push.
    store.put_at(sentinel, &Bytes(vec![7u8; 8]))?;
    let woken = armed.wait()?.map(|b: Bytes| b.0.len());
    println!("# pre-rebalance watch fired after 2 membership changes: {woken:?}");

    // The pre-rebalance proxy still resolves: its stale generation-0
    // descriptor re-attaches to the live control plane.
    let shipped: Proxy<Bytes> = Proxy::from_bytes(&early_wire)?;
    shipped.factory().invalidate_cache();
    println!(
        "# pre-rebalance proxy resolves to {}B after 2 membership changes",
        shipped.resolve()?.0.len()
    );
    for key in &keys {
        if store.get::<Bytes>(key)?.is_none() {
            return Err(Error::Config(format!("key {key} lost by rebalance")));
        }
    }
    println!("# full key set converged: all {n_keys} objects resolvable");

    // The whole demo ran inside one process, so one registry snapshot
    // covers every layer it touched: kv client + server, shard router,
    // reactor pool, watch plane, store counters.
    let snap = telemetry::snapshot();
    println!(
        "\n# telemetry: {} active subsystems {:?}",
        snap.active_subsystems().len(),
        snap.active_subsystems()
    );
    println!("{}", snap.render());
    drop(servers);
    Ok(())
}

fn broker_shard_cmd(args: &Args) -> Result<()> {
    use proxystore::broker::{
        BrokerFabric, BrokerState, PartitionBroker, PartitionedConsumer,
        PartitionedProducer, Partitioner, ThrottledBroker,
    };
    use proxystore::codec::Bytes;
    use proxystore::testing::fail::FlakyBroker;
    use std::sync::Arc;

    let instances: usize = args.get_parse("instances", 4)?;
    let partitions: u32 = args.get_parse("partitions", 8)?;
    let events: usize = args.get_parse("events", 256)?;
    let size: usize = args.get_parse("size", 16 * 1024)?;
    println!(
        "broker-shard: instances={instances} partitions={partitions} \
         events={events} size={size}B"
    );

    // Each instance sits behind a contended throttled link, so the
    // single-instance bottleneck the fabric removes is actually present.
    let throttled = || {
        ThrottledBroker::wrap(
            Arc::new(BrokerState::new()) as Arc<dyn PartitionBroker>,
            Duration::from_micros(200),
            2.0e8,
        ) as Arc<dyn PartitionBroker>
    };
    let batch: Vec<(Option<String>, Bytes)> = (0..events)
        .map(|i| (None, Bytes(vec![i as u8; size])))
        .collect();
    let mb = (events * size) as f64 / 1e6;

    println!("\n# batched produce/fetch throughput: 1 instance vs {instances}");
    let mut baseline = 0.0;
    // Degenerate --instances 1 would re-run the identical measurement.
    let configs: Vec<usize> =
        if instances > 1 { vec![1, instances] } else { vec![1] };
    for n in configs {
        let fabric = BrokerFabric::new(
            (0..n).map(|_| throttled()).collect(),
            partitions,
        )?;
        let mut producer =
            PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
        let t0 = std::time::Instant::now();
        producer.produce_many("demo", batch.clone())?;
        let produce_s = t0.elapsed().as_secs_f64();

        let mut consumer = PartitionedConsumer::new(fabric, "demo", 0, 1)?;
        consumer.set_fetch_max(events as u32);
        let t0 = std::time::Instant::now();
        let mut seen = 0;
        while seen < events {
            seen += consumer.poll(Duration::from_secs(5))?.len();
        }
        let fetch_s = t0.elapsed().as_secs_f64();
        if n == 1 {
            baseline = fetch_s;
        }
        println!(
            "  [{n} instance{}] produce {:.1} MB/s, fetch {:.1} MB/s{}",
            if n == 1 { "" } else { "s" },
            mb / produce_s,
            mb / fetch_s,
            if n == 1 {
                String::new()
            } else {
                format!(" ({:.1}x fetch speedup)", baseline / fetch_s)
            },
        );
    }

    println!("\n# per-key ordering across the fabric");
    let fabric =
        BrokerFabric::new((0..instances).map(|_| throttled()).collect(), partitions)?;
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::ByKey);
    for i in 0..32u8 {
        producer.produce("ord", Some(&format!("key-{}", i % 4)), Bytes(vec![i]))?;
    }
    let mut consumer = PartitionedConsumer::new(fabric, "ord", 0, 1)?;
    let mut per_part: std::collections::HashMap<u32, Vec<u8>> =
        std::collections::HashMap::new();
    let mut n = 0;
    while n < 32 {
        for (p, e) in consumer.poll(Duration::from_secs(5))? {
            per_part.entry(p).or_default().push(e.payload.0[0]);
            n += 1;
        }
    }
    let ordered = per_part.values().all(|v| v.windows(2).all(|w| w[0] < w[1]));
    println!(
        "  32 keyed events over {} partitions, per-partition order preserved: \
         {ordered}",
        per_part.len()
    );

    println!("\n# failure injection: killing one instance");
    let flaky: Vec<Arc<FlakyBroker>> = (0..instances.max(2))
        .map(|_| FlakyBroker::wrap(Arc::new(BrokerState::new()) as _))
        .collect();
    let fabric = BrokerFabric::new(
        flaky.iter().map(|f| f.clone() as Arc<dyn PartitionBroker>).collect(),
        partitions,
    )?;
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
    flaky[0].set_down(true);
    let mut lost = 0;
    for i in 0..partitions {
        if producer.produce("flaky", None, Bytes(vec![i as u8])).is_err() {
            lost += 1;
        }
    }
    println!(
        "  instance 0 down: {}/{partitions} partitions unavailable \
         (no replication on the event channel — losses are explicit, \
         surviving partitions keep their order)",
        lost
    );
    flaky[0].set_down(false);
    producer.produce("flaky", None, Bytes(vec![0]))?;
    println!("  instance 0 restored: produce succeeds again");
    Ok(())
}

fn stats_cmd(args: &Args) -> Result<()> {
    use proxystore::codec::Bytes;
    use proxystore::kv::KvClient;
    use proxystore::net::ServerBuilder;
    use proxystore::metrics::telemetry;
    use proxystore::shard::ShardedConnector;
    use proxystore::store::{Connector, TcpKvConnector};
    use std::sync::Arc;

    let shards: usize = args.get_parse("shards", 2)?;
    let n_keys: usize = args.get_parse("keys", 64)?;
    let size: usize = args.get_parse("size", 4096)?;
    println!("stats: shards={shards} keys={n_keys} size={size}B");

    // A live fabric: real TCP KV servers behind the sharded router.
    let mut servers = Vec::with_capacity(shards);
    let mut backends: Vec<Arc<dyn Connector>> = Vec::with_capacity(shards);
    for _ in 0..shards {
        let server = ServerBuilder::new().spawn_kv()?;
        backends
            .push(Arc::new(TcpKvConnector::connect(server.addr)?)
                as Arc<dyn Connector>);
        servers.push(server);
    }
    let fabric = Arc::new(ShardedConnector::new(backends, 1, 0)?);
    let store = Store::new("stats", fabric);

    // Traced traffic: every driver-thread op below crosses the wire in a
    // trace envelope, so the snapshot carries client AND server spans.
    let trace = telemetry::start_trace("stats-demo");
    let objs: Vec<Bytes> =
        (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();
    let keys = store.put_many(&objs)?;
    let got: Vec<Option<Bytes>> = store.get_many(&keys)?;
    let hits = got.iter().filter(|b| b.is_some()).count();
    println!("put+get {n_keys} objects, {hits} hits");

    // Exercise the watch plane: arm, fulfil, wake.
    let armed = store.watch_async::<Bytes>("stats-sentinel");
    store.put_at("stats-sentinel", &Bytes(vec![1u8; 16]))?;
    armed.wait()?;
    drop(trace);

    // The wire path: ask a server for its registry snapshot over TCP.
    let client = KvClient::connect(servers[0].addr)?;
    let remote = client.telemetry()?;
    println!(
        "\n# snapshot fetched over the wire from {}: {} counters, \
         {} histograms, {} trace events",
        servers[0].addr,
        remote.counters.len(),
        remote.histograms.len(),
        remote.events.len(),
    );

    // The local view (same process, same registry): full exposition.
    let snap = telemetry::snapshot();
    println!(
        "# active subsystems: {:?}",
        snap.active_subsystems()
    );
    println!("{}", snap.render());
    Ok(())
}

fn obs_cmd(args: &Args) -> Result<()> {
    use proxystore::codec::Bytes;
    use proxystore::metrics::telemetry;
    use proxystore::metrics::{write_text_atomic, ClusterSnapshot, SpanNode};
    use proxystore::net::{http_get, ServerBuilder};
    use proxystore::shard::ShardedConnector;
    use proxystore::store::{Connector, TcpKvConnector};
    use std::sync::Arc;

    let shards: usize = args.get_parse("shards", 4)?;
    let n_keys: usize = args.get_parse("keys", 64)?;
    let size: usize = args.get_parse("size", 4096)?;
    let trace_out =
        args.get("trace-out").unwrap_or("results/obs.trace.json");
    println!("obs: shards={shards} keys={n_keys} size={size}B");

    // A live fabric with the admin plane enabled on the first server:
    // the same epoll reactor that serves the data plane answers HTTP.
    let mut servers = Vec::with_capacity(shards);
    let mut backends: Vec<Arc<dyn Connector>> = Vec::with_capacity(shards);
    for i in 0..shards {
        let mut b = ServerBuilder::new();
        if i == 0 {
            b = b.admin_addr("127.0.0.1:0".parse().unwrap());
        }
        let server = b.spawn_kv()?;
        backends
            .push(Arc::new(TcpKvConnector::connect(server.addr)?)
                as Arc<dyn Connector>);
        servers.push(server);
    }
    let fabric = Arc::new(ShardedConnector::new(backends, 1, 0)?);
    let store = Store::new("obs", fabric.clone());

    // Low threshold so this short demo's round-trips land in the
    // slow-op log; production keeps the 1ms default.
    telemetry::set_slow_threshold(Duration::from_micros(50));

    // Traced traffic: the client root span parents every per-shard
    // server span, so the merged view reassembles one tree per op.
    let trace = telemetry::start_trace("obs-demo");
    let trace_id = trace.ctx().trace_id;
    let objs: Vec<Bytes> =
        (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();
    let keys = store.put_many(&objs)?;
    let got: Vec<Option<Bytes>> = store.get_many(&keys)?;
    let hits = got.iter().filter(|b| b.is_some()).count();
    println!("put+get {n_keys} objects, {hits} hits");
    drop(trace);

    // Scrape the fabric: Telemetry op fanned to every shard over the
    // wire, merged with the local registry.
    let cs = ClusterSnapshot::scrape_sharded(&fabric);
    println!("\n{}", cs.render());

    // Cross-process span trees for the traced run.
    fn print_tree(node: &SpanNode, depth: usize) {
        println!(
            "  {:indent$}{}.{} {}us [{}] span={:x} parent={:x}",
            "",
            node.event.subsystem,
            node.event.name,
            node.event.dur_us,
            node.node,
            node.event.span_id,
            node.event.parent_span,
            indent = depth * 2,
        );
        for child in &node.children {
            print_tree(child, depth + 1);
        }
    }
    let trees = cs.span_trees_for(trace_id);
    let spans: usize = trees.iter().map(SpanNode::size).sum();
    println!("# trace {trace_id:016x}: {} trees, {spans} spans", trees.len());
    for tree in trees.iter().take(4) {
        print_tree(tree, 0);
    }
    if trees.len() > 4 {
        println!("  ... {} more trees", trees.len() - 4);
    }

    // Chrome trace-viewer export (load in Perfetto / chrome://tracing).
    let json = cs.chrome_trace();
    write_text_atomic(trace_out, &json)?;
    println!("\nwrote {trace_out} ({} bytes)", json.len());

    // The HTTP admin plane, scraped live over raw TCP.
    let admin = servers[0]
        .admin_addr()
        .ok_or_else(|| Error::Config("admin plane not spawned".into()))?;
    println!("\n# admin endpoint at http://{admin}");
    for path in ["/healthz", "/readyz", "/conns"] {
        let (status, body) = http_get(admin, path)?;
        println!("GET {path} -> {status}: {}", body.trim_end());
    }
    let (status, metrics) = http_get(admin, "/metrics")?;
    let families =
        metrics.lines().filter(|l| l.starts_with("# TYPE")).count();
    println!(
        "GET /metrics -> {status}: {} bytes, {families} metric families; \
         first lines:",
        metrics.len()
    );
    for line in metrics.lines().take(6) {
        println!("  {line}");
    }
    let (status, slow) = http_get(admin, "/slow")?;
    println!(
        "GET /slow -> {status}: {} slow ops over threshold",
        slow.lines().count()
    );
    Ok(())
}

fn persist_cmd(args: &Args) -> Result<()> {
    use proxystore::broker::BrokerClient;
    use proxystore::codec::Bytes;
    use proxystore::metrics::telemetry;
    use proxystore::persist::{DurabilityOptions, FsyncPolicy};
    use proxystore::store::TcpKvConnector;
    use proxystore::testing::fail::RestartableServer;
    use std::sync::Arc;

    let n_keys: usize = args.get_parse("keys", 128)?;
    let size: usize = args.get_parse("size", 4096)?;
    let data_dir = match args.get("data-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir()
            .join(format!("proxystore-persist-{}", std::process::id())),
    };
    println!(
        "persist: keys={n_keys} size={size}B data_dir={}",
        data_dir.display()
    );

    // --- KV shard: durable writes, hard kill, same-port restart. ---
    let kv_opts = DurabilityOptions::new(data_dir.join("kv-node"))
        .fsync(FsyncPolicy::EveryN(64))
        .snapshot_every_ops(n_keys.max(2) as u64 / 2);
    let mut kv = RestartableServer::kv(kv_opts)?;
    println!("\n# kv: durable shard on {}", kv.addr());
    let store = Store::new(
        "persist-kv",
        Arc::new(TcpKvConnector::connect(kv.addr())?),
    );
    let objs: Vec<Bytes> =
        (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();
    let keys = store.put_many(&objs)?;
    println!("  stored {n_keys} objects (WAL append + group commit per ack)");

    kv.kill();
    println!("  hard-killed: no shutdown handshake, in-memory state gone");
    kv.restart()?;
    let stats = kv
        .kv_state()
        .and_then(|s| s.recovery_stats())
        .ok_or_else(|| Error::Config("restarted kv is not durable".into()))?;
    println!(
        "  restarted on {}: recovered from snapshot seq {:?} + {} replayed \
         WAL records ({} truncated)",
        kv.addr(),
        stats.snapshot_seq,
        stats.replayed_records,
        stats.truncated_records,
    );
    let store = Store::new(
        "persist-kv-after",
        Arc::new(TcpKvConnector::connect(kv.addr())?),
    );
    let got: Vec<Option<Bytes>> = store.get_many(&keys)?;
    let hits = got.iter().filter(|b| b.is_some()).count();
    let intact = got.iter().zip(&objs).all(|(g, o)| g.as_ref() == Some(o));
    println!(
        "  {hits}/{n_keys} objects readable after restart, payloads \
         intact: {intact}"
    );
    if hits != n_keys || !intact {
        return Err(Error::Config("kv recovery lost data".into()));
    }

    // --- Broker: durable topic log + committed offsets across restart. ---
    let events = 32u64;
    let broker_opts = DurabilityOptions::new(data_dir.join("broker-node"))
        .fsync(FsyncPolicy::EveryOp);
    let mut broker = RestartableServer::broker(broker_opts)?;
    println!("\n# broker: durable log on {}", broker.addr());
    let client = BrokerClient::connect(broker.addr())?;
    for i in 0..events {
        client.produce("persist-demo", Bytes(vec![i as u8; 64]))?;
    }
    client.commit("replayers", "persist-demo", events / 2)?;
    println!(
        "  produced {events} events (fsync per ack), committed offset {}",
        events / 2
    );
    drop(client);

    broker.kill();
    broker.restart()?;
    let bstats = broker
        .broker_state()
        .and_then(|s| s.recovery_stats())
        .ok_or_else(|| Error::Config("restarted broker not durable".into()))?;
    let client = BrokerClient::connect(broker.addr())?;
    let end = client.end_offset("persist-demo")?;
    let committed = client.committed("replayers", "persist-demo")?;
    let entries =
        client.fetch("persist-demo", 0, events as u32, Duration::ZERO)?;
    let ordered = entries.iter().enumerate().all(|(i, e)| {
        e.offset == i as u64 && e.payload.0 == vec![i as u8; 64]
    });
    println!(
        "  restarted on {}: {} records replayed, end offset {end}, \
         committed offset {committed}, {} entries refetched in order: \
         {ordered}",
        broker.addr(),
        bstats.replayed_records,
        entries.len(),
    );
    if end != events
        || committed != events / 2
        || entries.len() != events as usize
        || !ordered
    {
        return Err(Error::Config("broker recovery lost data".into()));
    }

    // --- What recovery reads: the data-dir layout. ---
    println!("\n# data dir layout ({}):", data_dir.display());
    let mut files = Vec::new();
    list_files(&data_dir, &data_dir, &mut files)?;
    for line in &files {
        println!("  {line}");
    }

    let snap = telemetry::snapshot();
    println!("\n# durability telemetry:");
    for line in snap.render().lines() {
        if line.contains("wal.")
            || line.contains("snapshot.")
            || line.contains("recovery.")
        {
            println!("  {line}");
        }
    }
    Ok(())
}

/// Recursively collect `relative-path sizeB` lines for every file under
/// `dir`, sorted, so the persist scenario's data-dir listing is stable.
fn list_files(
    root: &std::path::Path,
    dir: &std::path::Path,
    out: &mut Vec<String>,
) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .map_err(Error::from)?
        .filter_map(|e| e.ok())
        .collect();
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            list_files(root, &path, out)?;
        } else {
            let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
            out.push(format!(
                "{} {len}B",
                path.strip_prefix(root).unwrap_or(&path).display()
            ));
        }
    }
    Ok(())
}

fn serve_kv() -> Result<()> {
    use std::io::Write as _;
    let server = proxystore::net::ServerBuilder::new()
        .admin_addr("127.0.0.1:0".parse().unwrap())
        .spawn_kv()?;
    println!("redis-sim KV server listening on {}", server.addr);
    if let Some(admin) = server.admin_addr() {
        println!(
            "admin plane at {admin} (/metrics /healthz /readyz /conns \
             /trace /slow)"
        );
    }
    println!("(ctrl-c to stop)");
    // Supervisors read these lines through a pipe: flush past the
    // block-buffering stdout switches to when it isn't a terminal.
    std::io::stdout().flush()?;
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn serve_broker() -> Result<()> {
    use std::io::Write as _;
    let server = proxystore::net::ServerBuilder::new()
        .admin_addr("127.0.0.1:0".parse().unwrap())
        .spawn_broker()?;
    println!("log broker listening on {}", server.addr);
    if let Some(admin) = server.admin_addr() {
        println!(
            "admin plane at {admin} (/metrics /healthz /readyz /conns \
             /trace /slow)"
        );
    }
    println!("(ctrl-c to stop)");
    std::io::stdout().flush()?;
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}
