//! Concurrent read-load probe: the shared harness behind the elastic
//! fabric's zero-read-miss checks (integration tests, `benches/rebalance`,
//! and the `rebalance` CLI scenario all drive the same probe).
//!
//! Reader threads hammer a fixed key set — every key fully written before
//! the probe starts — and count each get that does not return the object
//! (a miss *or* an error). Read-through migration promises that count
//! stays zero while shards come and go.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::codec::Bytes;
use crate::store::Store;

/// Handle over running reader threads; [`ReadProbe::finish`] stops them
/// and reports `(reads, misses)`.
pub struct ReadProbe {
    stop: Arc<AtomicBool>,
    reads: Arc<AtomicU64>,
    misses: Arc<AtomicU64>,
    readers: Vec<std::thread::JoinHandle<()>>,
}

impl ReadProbe {
    /// Spawn `threads` readers looping over `keys` (values must decode as
    /// [`Bytes`], which is what every fabric scenario stores).
    pub fn spawn(store: &Store, keys: &[String], threads: usize) -> ReadProbe {
        let stop = Arc::new(AtomicBool::new(false));
        let reads = Arc::new(AtomicU64::new(0));
        let misses = Arc::new(AtomicU64::new(0));
        let readers = (0..threads)
            .map(|r| {
                let store = store.clone();
                let keys = keys.to_vec();
                let (stop, reads, misses) =
                    (stop.clone(), reads.clone(), misses.clone());
                std::thread::Builder::new()
                    .name(format!("read-probe-{r}"))
                    .spawn(move || {
                        // Stride co-prime with typical key counts so the
                        // threads don't read in lockstep.
                        let mut i = r;
                        while !stop.load(Ordering::Relaxed) {
                            let key = &keys[i % keys.len()];
                            match store.get::<Bytes>(key) {
                                Ok(Some(_)) => {}
                                _ => {
                                    misses.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            reads.fetch_add(1, Ordering::Relaxed);
                            i += 7;
                        }
                    })
                    .expect("spawn read-probe thread")
            })
            .collect();
        ReadProbe { stop, reads, misses, readers }
    }

    /// The shared stop flag (lets co-driven writer threads share the
    /// probe's lifetime).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Stop the readers and return `(reads, misses)`.
    pub fn finish(self) -> (u64, u64) {
        self.stop.store(true, Ordering::Relaxed);
        for r in self.readers {
            r.join().expect("read-probe thread");
        }
        (
            self.reads.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn probe_counts_hits_and_misses() {
        let store = Store::memory("probe");
        let keys = store
            .put_many(&(0..8).map(|i| Bytes(vec![i as u8])).collect::<Vec<_>>())
            .unwrap();
        let probe = ReadProbe::spawn(&store, &keys, 2);
        std::thread::sleep(Duration::from_millis(30));
        let (reads, misses) = probe.finish();
        assert!(reads > 0, "probe never read");
        assert_eq!(misses, 0, "misses on fully resident keys");

        // Evicted keys count as misses.
        store.evict(&keys[0]).unwrap();
        let probe = ReadProbe::spawn(&store, &keys[..1], 1);
        std::thread::sleep(Duration::from_millis(20));
        let (reads, misses) = probe.finish();
        assert_eq!(reads, misses, "every read of an evicted key must miss");
    }
}
