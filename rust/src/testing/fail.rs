//! Failure injection for connectors and broker instances.
//!
//! [`FlakyConnector`] wraps any channel and, while tripped via
//! [`FlakyConnector::set_down`], fails every operation with a connector
//! error — the shard fabric's replica-fallback tests and the failover
//! bench both drive dead-backend scenarios through it without real
//! processes to kill. It also injects configurable per-operation latency
//! ([`FlakyConnector::set_latency`]) so slow-shard scenarios — a backend
//! that answers, just late — are drivable too (the elastic rebalancer's
//! tests migrate through deliberately slow shards this way). The latency
//! injection rides the submission path: submitted ops pay the delay in
//! flight on dedicated completer threads, so slow-op tests exercise real
//! in-flight overlap rather than serialized sleeps — and the sleeps never
//! park the shared reactor pool's workers.
//! [`FlakyBroker`] is the same failure switch for a broker fabric
//! instance, so partition-unavailability scenarios are drivable from
//! tests as well.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::broker::{FetchReq, LogEntry, PartitionBroker};
use crate::codec::Bytes;
use crate::error::{Error, Result};
use crate::metrics::StoreBytes;
use crate::ops::{Op, OpResult, Pending};
use crate::store::{Blob, Connector, ConnectorDesc};

/// A connector whose backend can be "killed" and "revived" at will, and
/// slowed down with injected per-operation latency.
///
/// State lives behind an inner `Arc` so the submission path can hand it
/// to a completer thread: with latency injected, [`Connector::submit`]
/// pays the delay *in flight* rather than at submission, which is what
/// lets slow-op tests exercise real in-flight overlap (N submitted slow
/// ops cost ~one delay, not N).
pub struct FlakyConnector {
    shared: Arc<FlakyShared>,
}

struct FlakyShared {
    inner: Arc<dyn Connector>,
    down: AtomicBool,
    /// Injected latency per operation, in microseconds (0 = none).
    latency_us: AtomicU64,
    /// Operations rejected while down (diagnostics).
    rejected: AtomicU64,
    /// Operations that paid injected latency (diagnostics).
    delayed: AtomicU64,
}

impl FlakyShared {
    fn is_down(&self) -> bool {
        self.down.load(Ordering::SeqCst)
    }

    fn check(&self) -> Result<()> {
        let us = self.latency_us.load(Ordering::SeqCst);
        if us > 0 {
            self.delayed.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(us));
        }
        if self.is_down() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            Err(Error::Connector("injected failure: backend down".into()))
        } else {
            Ok(())
        }
    }
}

impl FlakyConnector {
    /// Wrap a channel, initially healthy and fast.
    pub fn wrap(inner: Arc<dyn Connector>) -> Arc<FlakyConnector> {
        Arc::new(FlakyConnector {
            shared: Arc::new(FlakyShared {
                inner,
                down: AtomicBool::new(false),
                latency_us: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                delayed: AtomicU64::new(0),
            }),
        })
    }

    /// Trip (true) or restore (false) the backend.
    pub fn set_down(&self, down: bool) {
        self.shared.down.store(down, Ordering::SeqCst);
    }

    pub fn is_down(&self) -> bool {
        self.shared.is_down()
    }

    /// Inject a fixed delay before every operation (batched calls pay it
    /// once, like a slow link rather than a slow disk). `Duration::ZERO`
    /// removes the injection.
    pub fn set_latency(&self, latency: Duration) {
        self.shared
            .latency_us
            .store(latency.as_micros() as u64, Ordering::SeqCst);
    }

    /// The currently injected per-operation latency.
    pub fn latency(&self) -> Duration {
        Duration::from_micros(self.shared.latency_us.load(Ordering::SeqCst))
    }

    /// Operations rejected while the backend was down.
    pub fn rejected_ops(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Operations that paid injected latency.
    pub fn delayed_ops(&self) -> u64 {
        self.shared.delayed.load(Ordering::Relaxed)
    }

    fn check(&self) -> Result<()> {
        self.shared.check()
    }
}

impl Connector for FlakyConnector {
    /// Descriptor of the wrapped channel: a reconnecting peer reaches the
    /// real backend (the injected failure is process-local by design).
    fn desc(&self) -> ConnectorDesc {
        self.shared.inner.desc()
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.check()?;
        self.shared.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Result<Option<Blob>> {
        self.check()?;
        self.shared.inner.get(key)
    }

    fn put_nx(&self, key: &str, data: Vec<u8>) -> Result<bool> {
        self.check()?;
        self.shared.inner.put_nx(key, data)
    }

    fn wait_get(
        &self,
        key: &str,
        timeout: Option<Duration>,
    ) -> Result<Option<Blob>> {
        self.check()?;
        self.shared.inner.wait_get(key, timeout)
    }

    /// Arm through the wrapped channel. Down-ness fails the arm up front
    /// (a dead backend cannot promise a future push); injected latency is
    /// paid in flight on a dedicated completer thread, like
    /// [`Connector::submit`]. The thread parks on the inner arm in
    /// slices, checking for abandonment (dropped handle, settled race),
    /// so a never-firing watch cannot leak a parked thread.
    fn watch(&self, key: &str) -> Pending<Blob> {
        let shared = self.shared.clone();
        if shared.latency_us.load(Ordering::SeqCst) == 0 {
            return match shared.check() {
                Ok(()) => shared.inner.watch(key),
                Err(e) => Pending::ready(Err(e)),
            };
        }
        let key = key.to_string();
        let (completer, handle) = crate::ops::pending();
        std::thread::Builder::new()
            .name("flaky-delay".into())
            .spawn(move || {
                if let Err(e) = shared.check() {
                    return completer.complete(Err(e));
                }
                let inner = shared.inner.watch(&key);
                loop {
                    match inner.wait_timeout(Duration::from_millis(100)) {
                        Ok(Some(v)) => return completer.complete(Ok(v)),
                        Ok(None) => {
                            if completer.abandoned() {
                                return;
                            }
                        }
                        Err(e) => return completer.complete(Err(e)),
                    }
                }
            })
            .expect("spawn flaky delay thread");
        handle
    }

    fn put_many(&self, items: Vec<(String, Vec<u8>)>) -> Result<()> {
        self.check()?;
        self.shared.inner.put_many(items)
    }

    fn get_many(&self, keys: &[String]) -> Result<Vec<Option<Blob>>> {
        self.check()?;
        self.shared.inner.get_many(keys)
    }

    fn delete_many(&self, keys: &[String]) -> Result<()> {
        self.check()?;
        self.shared.inner.delete_many(keys)
    }

    fn evict(&self, key: &str) -> Result<()> {
        self.check()?;
        self.shared.inner.evict(key)
    }

    fn exists(&self, key: &str) -> Result<bool> {
        self.check()?;
        self.shared.inner.exists(key)
    }

    fn exists_many(&self, keys: &[String]) -> Result<Vec<bool>> {
        self.check()?;
        self.shared.inner.exists_many(keys)
    }

    fn list_keys(&self) -> Result<Vec<String>> {
        self.check()?;
        self.shared.inner.list_keys()
    }

    fn len(&self) -> Result<usize> {
        self.check()?;
        self.shared.inner.len()
    }

    /// Pipelined-path injection: with latency set, the delay is paid *in
    /// flight* — submission returns immediately and a dedicated completer
    /// thread sleeps out the delay — so N submitted ops against a slow
    /// backend overlap (one delay wall-clock) instead of serializing at
    /// the submission site. A thread per delayed op is deliberate for
    /// this testing wrapper: sleeping jobs must never park the shared
    /// reactor pool's workers (its contract is short-lived jobs only),
    /// and dedicated threads keep overlap tests deterministic. Down-ness
    /// still fails at the same point as the blocking path: after the
    /// delay, before the backend.
    fn submit(&self, op: Op) -> Pending<OpResult> {
        if let Op::Watch { key } = op {
            // Watches may park indefinitely: route through the watch
            // plane (which itself injects down-ness and latency) rather
            // than parking a completer thread on an unbounded wait.
            return crate::ops::watch_result(self.watch(&key));
        }
        let shared = self.shared.clone();
        if shared.latency_us.load(Ordering::SeqCst) == 0 {
            return match shared.check() {
                Ok(()) => shared.inner.submit(op),
                Err(e) => Pending::ready(Err(e)),
            };
        }
        let (completer, handle) = crate::ops::pending();
        std::thread::Builder::new()
            .name("flaky-delay".into())
            .spawn(move || {
                let result =
                    shared.check().and_then(|()| shared.inner.submit(op).wait());
                completer.complete(result);
            })
            .expect("spawn flaky delay thread");
        handle
    }

    fn submits_nonblocking(&self) -> bool {
        // With latency injected the delay moves to the reactor, making
        // submission itself nonblocking; otherwise we are whatever the
        // wrapped channel is.
        self.shared.latency_us.load(Ordering::SeqCst) > 0
            || self.shared.inner.submits_nonblocking()
    }

    fn gauge(&self) -> Option<Arc<StoreBytes>> {
        self.shared.inner.gauge()
    }
}

/// A broker instance whose backend can be "killed" and "revived" at will
/// (the [`FlakyConnector`] of the partitioned broker fabric).
pub struct FlakyBroker {
    inner: Arc<dyn PartitionBroker>,
    down: AtomicBool,
    rejected: AtomicU64,
}

impl FlakyBroker {
    /// Wrap a broker instance, initially healthy.
    pub fn wrap(inner: Arc<dyn PartitionBroker>) -> Arc<FlakyBroker> {
        Arc::new(FlakyBroker {
            inner,
            down: AtomicBool::new(false),
            rejected: AtomicU64::new(0),
        })
    }

    /// Trip (true) or restore (false) the instance.
    pub fn set_down(&self, down: bool) {
        self.down.store(down, Ordering::SeqCst);
    }

    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::SeqCst)
    }

    /// Operations rejected while the instance was down.
    pub fn rejected_ops(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    fn check(&self) -> Result<()> {
        if self.is_down() {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            Err(Error::Connector("injected failure: broker down".into()))
        } else {
            Ok(())
        }
    }
}

impl PartitionBroker for FlakyBroker {
    fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> Result<u64> {
        self.check()?;
        self.inner.produce_to(topic, partition, payload)
    }

    fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Result<Vec<u64>> {
        self.check()?;
        self.inner.produce_many(topic, partition, payloads)
    }

    fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>> {
        self.check()?;
        self.inner.fetch_from(topic, partition, offset, max, timeout)
    }

    fn fetch_many(
        &self,
        reqs: &[FetchReq],
        timeout: Duration,
    ) -> Result<Vec<Vec<LogEntry>>> {
        self.check()?;
        self.inner.fetch_many(reqs, timeout)
    }

    fn commit_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()> {
        self.check()?;
        self.inner.commit_part(group, topic, partition, offset)
    }

    fn committed_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
    ) -> Result<u64> {
        self.check()?;
        self.inner.committed_part(group, topic, partition)
    }

    fn end_offset_of(&self, topic: &str, partition: u32) -> Result<u64> {
        self.check()?;
        self.inner.end_offset_of(topic, partition)
    }
}

// ---------------------------------------------------------------------------
// Crash/restart harness
// ---------------------------------------------------------------------------

enum RestartHandle {
    Kv(Option<crate::kv::KvServer>),
    Broker(Option<crate::broker::BrokerServer>),
}

/// A KV or broker server that can be hard-killed and restarted on the
/// **same port and data dir** — the crash-recovery test double.
///
/// "Kill" drops the server handle with no flush, no snapshot, no
/// goodbye: exactly what a `kill -9` leaves behind. Whatever survives is
/// whatever the durability plane's fsync policy already put on disk.
/// "Restart" rebinds the original address (with a short retry while the
/// OS releases the listener) and re-opens the same
/// [`DurabilityOptions`], so the new process-equivalent recovers via
/// snapshot + WAL replay and serves the keys its predecessor acked.
///
/// ```no_run
/// use proxystore::persist::DurabilityOptions;
/// use proxystore::testing::fail::RestartableServer;
///
/// let opts = DurabilityOptions::new("/tmp/crash-test");
/// let mut server = RestartableServer::kv(opts).unwrap();
/// let addr = server.addr();
/// // ... write through a client, then:
/// server.kill();
/// server.restart().unwrap();
/// assert_eq!(server.addr(), addr); // same address, recovered state
/// ```
pub struct RestartableServer {
    addr: std::net::SocketAddr,
    opts: crate::persist::DurabilityOptions,
    handle: RestartHandle,
}

impl RestartableServer {
    /// Spawn a durable KV server on an ephemeral port.
    pub fn kv(opts: crate::persist::DurabilityOptions) -> Result<Self> {
        let server =
            crate::net::ServerBuilder::new().durability(opts.clone()).spawn_kv()?;
        Ok(RestartableServer {
            addr: server.addr,
            opts,
            handle: RestartHandle::Kv(Some(server)),
        })
    }

    /// Spawn a durable broker server on an ephemeral port.
    pub fn broker(opts: crate::persist::DurabilityOptions) -> Result<Self> {
        let server = crate::net::ServerBuilder::new()
            .durability(opts.clone())
            .spawn_broker()?;
        Ok(RestartableServer {
            addr: server.addr,
            opts,
            handle: RestartHandle::Broker(Some(server)),
        })
    }

    /// The address this server serves on — stable across restarts.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The durability options every incarnation opens.
    pub fn options(&self) -> &crate::persist::DurabilityOptions {
        &self.opts
    }

    pub fn is_running(&self) -> bool {
        match &self.handle {
            RestartHandle::Kv(h) => h.is_some(),
            RestartHandle::Broker(h) => h.is_some(),
        }
    }

    /// Hard-kill: drop the server with no flush or snapshot. Connected
    /// clients see a dead pipe; unsynced WAL tail records are lost,
    /// mimicking a process crash.
    pub fn kill(&mut self) {
        match &mut self.handle {
            RestartHandle::Kv(h) => drop(h.take()),
            RestartHandle::Broker(h) => drop(h.take()),
        }
    }

    /// Restart on the same address + data dir, recovering engine state
    /// from disk. Retries the bind briefly (the dying listener's socket
    /// may take a beat to release even with `SO_REUSEADDR`).
    pub fn restart(&mut self) -> Result<()> {
        if self.is_running() {
            return Err(Error::Config("server already running".into()));
        }
        let mut last = Error::Config("restart never attempted".into());
        for _ in 0..50 {
            let builder = crate::net::ServerBuilder::new()
                .bind(self.addr)
                .durability(self.opts.clone());
            let result = match &mut self.handle {
                RestartHandle::Kv(slot) => builder.spawn_kv().map(|s| {
                    *slot = Some(s);
                }),
                RestartHandle::Broker(slot) => builder.spawn_broker().map(|s| {
                    *slot = Some(s);
                }),
            };
            match result {
                Ok(()) => return Ok(()),
                Err(e) => last = e,
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        Err(last)
    }

    /// The live KV engine, when running as a KV server.
    pub fn kv_state(&self) -> Option<&crate::kv::KvState> {
        match &self.handle {
            RestartHandle::Kv(Some(s)) => Some(s.state()),
            _ => None,
        }
    }

    /// The live broker engine, when running as a broker.
    pub fn broker_state(&self) -> Option<&crate::broker::BrokerState> {
        match &self.handle {
            RestartHandle::Broker(Some(s)) => Some(s.state()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemoryConnector;

    #[test]
    fn flaky_broker_trips_and_recovers() {
        let state = crate::broker::BrokerState::new();
        let flaky =
            FlakyBroker::wrap(Arc::new(state) as Arc<dyn PartitionBroker>);
        flaky.produce_to("t", 0, Bytes(vec![1])).unwrap();
        flaky.set_down(true);
        assert!(flaky.produce_to("t", 0, Bytes(vec![2])).is_err());
        assert!(flaky
            .fetch_from("t", 0, 0, 1, Duration::ZERO)
            .is_err());
        assert_eq!(flaky.rejected_ops(), 2);
        flaky.set_down(false);
        let got = flaky.fetch_from("t", 0, 0, 10, Duration::ZERO).unwrap();
        assert_eq!(got.len(), 1, "log survived the outage");
    }

    #[test]
    fn healthy_passthrough_then_injected_failure() {
        let flaky = FlakyConnector::wrap(MemoryConnector::new());
        flaky.put("k", vec![1]).unwrap();
        assert_eq!(flaky.get("k").unwrap().map(|b| b.to_vec()), Some(vec![1]));
        assert_eq!(flaky.rejected_ops(), 0);

        flaky.set_down(true);
        assert!(flaky.get("k").is_err());
        assert!(flaky.put("k2", vec![2]).is_err());
        assert!(flaky.exists("k").is_err());
        assert!(flaky.get_many(&["k".into()]).is_err());
        assert_eq!(flaky.rejected_ops(), 4);

        // Data survives the outage: the backend was never really gone.
        flaky.set_down(false);
        assert_eq!(flaky.get("k").unwrap().map(|b| b.to_vec()), Some(vec![1]));
    }

    #[test]
    fn injected_latency_slows_but_does_not_fail() {
        let flaky = FlakyConnector::wrap(MemoryConnector::new());
        flaky.put("k", vec![1]).unwrap();
        assert_eq!(flaky.delayed_ops(), 0);

        flaky.set_latency(Duration::from_millis(5));
        assert_eq!(flaky.latency(), Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        assert_eq!(flaky.get("k").unwrap().map(|b| b.to_vec()), Some(vec![1]));
        assert!(
            t0.elapsed() >= Duration::from_millis(5),
            "injected latency not paid"
        );
        // Batched ops pay the delay once per call, and still succeed.
        assert_eq!(flaky.exists_many(&["k".into()]).unwrap(), vec![true]);
        assert_eq!(flaky.delayed_ops(), 2);

        // Latency composes with failure injection: slow AND down fails.
        flaky.set_down(true);
        assert!(flaky.get("k").is_err());
        flaky.set_down(false);
        flaky.set_latency(Duration::ZERO);
        let t0 = std::time::Instant::now();
        flaky.get("k").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn injected_latency_overlaps_submitted_ops() {
        let flaky = FlakyConnector::wrap(MemoryConnector::new());
        flaky.set_latency(Duration::from_millis(80));
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                flaky.submit(crate::ops::Op::Put {
                    key: format!("ov-{i}"),
                    data: vec![i as u8],
                })
            })
            .collect();
        // Submission is nonblocking: the delay moved in flight.
        assert!(flaky.submits_nonblocking());
        assert!(
            t0.elapsed() < Duration::from_millis(60),
            "submission paid the injected delay"
        );
        for h in handles {
            h.wait().unwrap().into_unit().unwrap();
        }
        let total = t0.elapsed();
        // 4 x 80ms serial = 320ms; the bound leaves one extra wave of
        // slack for contention on the process-global pool from tests
        // running in parallel, while still proving in-flight overlap.
        assert!(total < Duration::from_millis(240), "no overlap: {total:?}");
        flaky.set_latency(Duration::ZERO);
        assert!(!flaky.submits_nonblocking());
        assert_eq!(flaky.delayed_ops(), 4);
        for i in 0..4 {
            assert!(flaky.exists(&format!("ov-{i}")).unwrap());
        }
    }

    #[test]
    fn submit_while_down_fails_without_backend_touch() {
        let flaky = FlakyConnector::wrap(MemoryConnector::new());
        flaky.set_down(true);
        assert!(flaky
            .submit(crate::ops::Op::Put { key: "k".into(), data: vec![1] })
            .wait()
            .is_err());
        flaky.set_down(false);
        assert!(!flaky.exists("k").unwrap());
        assert_eq!(flaky.rejected_ops(), 1);
    }
}
