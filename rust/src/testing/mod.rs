//! Minimal property-testing framework (proptest is not in the offline
//! registry).
//!
//! Provides [`Gen`] combinators over the crate's deterministic
//! [`Rng`](crate::rng::Rng), a [`forall`] runner with seeded cases and
//! greedy shrinking, and standard generators for the types the stack's
//! invariants range over. On failure the runner reports the *shrunk*
//! counterexample plus the seed to reproduce it.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath flags)
//! use proxystore::testing::{forall, gens};
//! forall(gens::vec(gens::u64(0..1000), 0..50), 100, |xs| {
//!     let mut sorted = xs.clone();
//!     sorted.sort();
//!     sorted.len() == xs.len()
//! });
//! ```

use crate::rng::Rng;

pub mod fail;
pub mod load;

/// A generator of values plus their shrink candidates.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate simplifications of `value` (smaller-first preferred).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` on `cases` generated inputs; panics with the shrunk
/// counterexample on failure. Deterministic given `PROXYSTORE_PROP_SEED`
/// (default 0xC0FFEE).
pub fn forall<G: Gen>(
    gen: G,
    cases: usize,
    mut prop: impl FnMut(&G::Value) -> bool,
) {
    let seed = std::env::var("PROXYSTORE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let shrunk = shrink_to_minimal(&gen, value, &mut prop);
            panic!(
                "property failed (seed={seed}, case={case});\n\
                 minimal counterexample: {shrunk:#?}"
            );
        }
    }
}

/// Greedy shrink: repeatedly take the first failing shrink candidate.
fn shrink_to_minimal<G: Gen>(
    gen: &G,
    mut value: G::Value,
    prop: &mut impl FnMut(&G::Value) -> bool,
) -> G::Value {
    let mut budget = 1000;
    'outer: while budget > 0 {
        for candidate in gen.shrink(&value) {
            budget -= 1;
            if !prop(&candidate) {
                value = candidate;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    value
}

/// Standard generators.
pub mod gens {
    use super::Gen;
    use crate::rng::Rng;
    use std::ops::Range;

    /// Uniform u64 in a range, shrinking toward the lower bound.
    pub struct U64(pub Range<u64>);

    pub fn u64(range: Range<u64>) -> U64 {
        U64(range)
    }

    impl Gen for U64 {
        type Value = u64;

        fn generate(&self, rng: &mut Rng) -> u64 {
            self.0.start + rng.gen_range(self.0.end - self.0.start)
        }

        fn shrink(&self, v: &u64) -> Vec<u64> {
            let lo = self.0.start;
            if *v == lo {
                return Vec::new();
            }
            let mut out = vec![lo];
            let mid = lo + (v - lo) / 2;
            if mid != lo && mid != *v {
                out.push(mid);
            }
            out.push(v - 1);
            out
        }
    }

    /// usize in a range.
    pub struct USize(pub Range<usize>);

    pub fn usize(range: Range<usize>) -> USize {
        USize(range)
    }

    impl Gen for USize {
        type Value = usize;

        fn generate(&self, rng: &mut Rng) -> usize {
            rng.usize_in(self.0.start, self.0.end)
        }

        fn shrink(&self, v: &usize) -> Vec<usize> {
            U64(self.0.start as u64..self.0.end as u64)
                .shrink(&(*v as u64))
                .into_iter()
                .map(|x| x as usize)
                .collect()
        }
    }

    /// f64 in [0, 1).
    pub struct UnitF64;

    pub fn unit_f64() -> UnitF64 {
        UnitF64
    }

    impl Gen for UnitF64 {
        type Value = f64;

        fn generate(&self, rng: &mut Rng) -> f64 {
            rng.f64()
        }

        fn shrink(&self, v: &f64) -> Vec<f64> {
            if *v == 0.0 {
                Vec::new()
            } else {
                vec![0.0, v / 2.0]
            }
        }
    }

    /// Bool with probability 1/2.
    pub struct Boolean;

    pub fn boolean() -> Boolean {
        Boolean
    }

    impl Gen for Boolean {
        type Value = bool;

        fn generate(&self, rng: &mut Rng) -> bool {
            rng.chance(0.5)
        }

        fn shrink(&self, v: &bool) -> Vec<bool> {
            if *v { vec![false] } else { Vec::new() }
        }
    }

    /// Vec of `inner` with length in `len`, shrinking by halving and by
    /// element shrinks on the first element.
    pub struct VecGen<G> {
        inner: G,
        len: Range<usize>,
    }

    pub fn vec<G: Gen>(inner: G, len: Range<usize>) -> VecGen<G> {
        VecGen { inner, len }
    }

    impl<G: Gen> Gen for VecGen<G> {
        type Value = Vec<G::Value>;

        fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
            let n = rng.usize_in(self.len.start, self.len.end.max(self.len.start + 1));
            (0..n).map(|_| self.inner.generate(rng)).collect()
        }

        fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
            let mut out = Vec::new();
            if v.len() > self.len.start {
                out.push(v[..self.len.start].to_vec());
                out.push(v[..v.len() / 2].to_vec());
                let mut minus_one = v.clone();
                minus_one.pop();
                out.push(minus_one);
            }
            if let Some(first) = v.first() {
                for s in self.inner.shrink(first) {
                    let mut copy = v.clone();
                    copy[0] = s;
                    out.push(copy);
                }
            }
            out.retain(|c| c.len() >= self.len.start);
            out
        }
    }

    /// Byte payloads (wraps `vec(u64)` for speed on large buffers).
    pub struct BytesGen {
        len: Range<usize>,
    }

    pub fn bytes(len: Range<usize>) -> BytesGen {
        BytesGen { len }
    }

    impl Gen for BytesGen {
        type Value = Vec<u8>;

        fn generate(&self, rng: &mut Rng) -> Vec<u8> {
            let n = rng.usize_in(self.len.start, self.len.end.max(self.len.start + 1));
            rng.bytes(n)
        }

        fn shrink(&self, v: &Vec<u8>) -> Vec<Vec<u8>> {
            if v.len() <= self.len.start {
                return Vec::new();
            }
            vec![v[..self.len.start].to_vec(), v[..v.len() / 2].to_vec()]
        }
    }

    /// ASCII strings.
    pub struct StringGen {
        len: Range<usize>,
    }

    pub fn string(len: Range<usize>) -> StringGen {
        StringGen { len }
    }

    impl Gen for StringGen {
        type Value = String;

        fn generate(&self, rng: &mut Rng) -> String {
            let n = rng.usize_in(self.len.start, self.len.end.max(self.len.start + 1));
            (0..n)
                .map(|_| (b'a' + rng.gen_range(26) as u8) as char)
                .collect()
        }

        fn shrink(&self, v: &String) -> Vec<String> {
            if v.len() <= self.len.start {
                return Vec::new();
            }
            vec![
                v[..self.len.start].to_string(),
                v[..v.len() / 2].to_string(),
            ]
        }
    }

    /// Pair of independent generators.
    pub struct PairGen<A, B>(pub A, pub B);

    pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> PairGen<A, B> {
        PairGen(a, b)
    }

    impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
        type Value = (A::Value, B::Value);

        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }

        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> = self
                .0
                .shrink(&v.0)
                .into_iter()
                .map(|a| (a, v.1.clone()))
                .collect();
            out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
            out
        }
    }

    /// One of a fixed set of values.
    pub struct OneOf<T> {
        choices: Vec<T>,
    }

    pub fn one_of<T: Clone + std::fmt::Debug>(choices: &[T]) -> OneOf<T> {
        assert!(!choices.is_empty());
        OneOf { choices: choices.to_vec() }
    }

    impl<T: Clone + std::fmt::Debug> Gen for OneOf<T> {
        type Value = T;

        fn generate(&self, rng: &mut Rng) -> T {
            self.choices[rng.usize_in(0, self.choices.len())].clone()
        }

        fn shrink(&self, _v: &T) -> Vec<T> {
            vec![self.choices[0].clone()]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(gens::u64(0..100), 200, |&x| x < 100);
        forall(gens::vec(gens::u64(0..10), 0..20), 100, |v| v.len() < 20);
        forall(gens::bytes(0..100), 50, |b| b.len() < 100);
        forall(gens::string(1..8), 50, |s| !s.is_empty());
        forall(
            gens::pair(gens::u64(0..5), gens::boolean()),
            50,
            |(a, _)| *a < 5,
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            forall(gens::u64(0..1000), 500, |&x| x < 50);
        });
        let msg = match result {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy shrink must land on exactly 50.
        assert!(msg.contains("50"), "unshrunk counterexample: {msg}");
    }

    #[test]
    fn vec_shrink_respects_min_len() {
        let g = gens::vec(gens::u64(0..10), 2..10);
        let candidates = g.shrink(&vec![1, 2, 3, 4]);
        assert!(candidates.iter().all(|c| c.len() >= 2));
    }

    #[test]
    fn deterministic_given_seed() {
        std::env::remove_var("PROXYSTORE_PROP_SEED");
        let mut first = Vec::new();
        forall(gens::u64(0..1_000_000), 10, |&x| {
            first.push(x);
            true
        });
        let mut second = Vec::new();
        forall(gens::u64(0..1_000_000), 10, |&x| {
            second.push(x);
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn one_of_only_yields_choices() {
        forall(gens::one_of(&["a", "b", "c"]), 100, |s| {
            ["a", "b", "c"].contains(s)
        });
    }
}
