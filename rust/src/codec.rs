//! Binary serialization substrate (the stack's "pickle").
//!
//! The offline registry has no serde, so proxystore ships its own compact
//! little-endian codec: fixed-width primitives, LEB128 varint lengths, and
//! derive-free [`Encode`]/[`Decode`] traits implemented over the std
//! containers the stack uses. All wire formats (KV protocol, broker frames,
//! stream events, proxy factories, task payloads) are built from these
//! primitives, so a codec round-trip property test covers the whole stack's
//! framing.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Serialize `self` onto the end of `buf`.
pub trait Encode {
    fn encode(&self, buf: &mut Vec<u8>);

    /// Convenience: encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode(&mut buf);
        buf
    }
}

/// Deserialize a value from a [`Reader`].
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self>;

    /// Convenience: decode from a complete buffer, requiring full consumption.
    fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut r = Reader::new(data);
        let v = Self::decode(&mut r)?;
        if !r.is_empty() {
            return Err(Error::Codec(format!(
                "{} trailing bytes after decode",
                r.remaining()
            )));
        }
        Ok(v)
    }

    /// Decode from an owned buffer. The default delegates to
    /// [`Decode::from_bytes`]; bulk types override it to reuse the
    /// allocation (e.g. [`Bytes`] shifts off its header in place), which
    /// is the zero-copy tail of proxy resolution on single-owner blobs.
    fn from_owned(data: Vec<u8>) -> Result<Self> {
        Self::from_bytes(&data)
    }
}

/// Cursor over a byte slice.
pub struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Reader { data, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Take `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Codec(format!(
                "need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }
}

// --------------------------------------------------------------------------
// Varints (LEB128) for lengths and discriminants.
// --------------------------------------------------------------------------

/// Append a LEB128 varint.
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint.
pub fn get_varint(r: &mut Reader<'_>) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = r.take(1)?[0];
        if shift >= 64 {
            return Err(Error::Codec("varint overflow".into()));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn get_len(r: &mut Reader<'_>) -> Result<usize> {
    let v = get_varint(r)?;
    // Defensive cap: decoding never allocates more than the input could
    // plausibly describe (protects servers from hostile length prefixes).
    if v > (r.remaining() as u64).saturating_mul(8).saturating_add(1 << 20) {
        return Err(Error::Codec(format!("length {v} exceeds input")));
    }
    Ok(v as usize)
}

// --------------------------------------------------------------------------
// Primitive impls
// --------------------------------------------------------------------------

macro_rules! impl_fixed {
    ($($t:ty),*) => {$(
        impl Encode for $t {
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }
        }
        impl Decode for $t {
            fn decode(r: &mut Reader<'_>) -> Result<Self> {
                Ok(<$t>::from_le_bytes(r.take_array()?))
            }
        }
    )*};
}

impl_fixed!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Encode for usize {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, *self as u64);
    }
}
impl Decode for usize {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(get_varint(r)? as usize)
    }
}

impl Encode for bool {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.push(u8::from(*self));
    }
}
impl Decode for bool {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        match r.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(Error::Codec(format!("invalid bool byte {b}"))),
        }
    }
}

impl Encode for String {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        buf.extend_from_slice(self.as_bytes());
    }
}
impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let raw = r.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| Error::Codec(format!("invalid utf8: {e}")))
    }
}

impl Encode for &str {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        buf.extend_from_slice(self.as_bytes());
    }
}

/// Bulk byte payload with memcpy encoding (vs the element-wise `Vec<T>`).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Bytes(pub Vec<u8>);

impl Encode for Bytes {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.reserve(self.0.len() + 10);
        put_varint(buf, self.0.len() as u64);
        buf.extend_from_slice(&self.0);
    }

    fn to_bytes(&self) -> Vec<u8> {
        // Exact-capacity fast path: one allocation, one memcpy.
        let mut buf = Vec::with_capacity(self.0.len() + 10);
        self.encode(&mut buf);
        buf
    }
}
impl Decode for Bytes {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        Ok(Bytes(r.take(n)?.to_vec()))
    }

    fn from_owned(mut data: Vec<u8>) -> Result<Self> {
        // Validate the header, then shift it off in place (memmove, no
        // allocation) instead of copying the payload out.
        let header_len = {
            let mut r = Reader::new(&data);
            let n = get_len(&mut r)?;
            let h = data.len() - r.remaining();
            if r.remaining() != n {
                return Err(Error::Codec(format!(
                    "bytes payload {} != declared {n}",
                    r.remaining()
                )));
            }
            h
        };
        data.drain(..header_len);
        Ok(Bytes(data))
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        for item in self {
            item.encode(buf);
        }
    }
}
impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let mut v = Vec::with_capacity(n.min(r.remaining()));
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            None => buf.push(0),
            Some(v) => {
                buf.push(1);
                v.encode(buf);
            }
        }
    }
}
impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        match r.take(1)?[0] {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            b => Err(Error::Codec(format!("invalid option tag {b}"))),
        }
    }
}

impl<K: Encode + Ord, V: Encode> Encode for BTreeMap<K, V> {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        for (k, v) in self {
            k.encode(buf);
            v.encode(buf);
        }
    }
}
impl<K: Decode + Ord, V: Decode> Decode for BTreeMap<K, V> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let mut m = BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

macro_rules! impl_tuple {
    ($($name:ident : $idx:tt),+) => {
        impl<$($name: Encode),+> Encode for ($($name,)+) {
            fn encode(&self, buf: &mut Vec<u8>) {
                $(self.$idx.encode(buf);)+
            }
        }
        impl<$($name: Decode),+> Decode for ($($name,)+) {
            fn decode(r: &mut Reader<'_>) -> Result<Self> {
                Ok(($($name::decode(r)?,)+))
            }
        }
    };
}

impl_tuple!(A: 0);
impl_tuple!(A: 0, B: 1);
impl_tuple!(A: 0, B: 1, C: 2);
impl_tuple!(A: 0, B: 1, C: 2, D: 3);

/// Encode a `Vec<f32>` as raw little-endian words (bulk numeric payloads;
/// 4 bytes/elem, memcpy on both sides for the PJRT buffer path).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct F32s(pub Vec<f32>);

impl Encode for F32s {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.reserve(self.0.len() * 4 + 10);
        put_varint(buf, self.0.len() as u64);
        // Safe, portable memcpy: chunk through to_le_bytes in bulk.
        for chunk in self.0.chunks(1024) {
            for v in chunk {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}
impl Decode for F32s {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let raw = r.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(F32s(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        let back = T::from_bytes(&bytes).expect("decode");
        assert_eq!(v, back);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u64::MAX);
        roundtrip(i64::MIN);
        roundtrip(-1.5f32);
        roundtrip(f64::consts_check());
        roundtrip(true);
        roundtrip(false);
        roundtrip(usize::MAX);
    }

    trait ConstsCheck {
        fn consts_check() -> f64 {
            std::f64::consts::PI
        }
    }
    impl ConstsCheck for f64 {}

    #[test]
    fn strings_roundtrip() {
        roundtrip(String::new());
        roundtrip("hello world".to_string());
        roundtrip("ünïcødé 🎉".to_string());
    }

    #[test]
    fn containers_roundtrip() {
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(Vec::<String>::new());
        roundtrip(Some("x".to_string()));
        roundtrip(Option::<u64>::None);
        roundtrip(Bytes(vec![0u8, 1, 2, 255]));
        roundtrip(F32s(vec![1.0, -2.5, f32::MAX]));
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 1u64);
        m.insert("b".to_string(), 2u64);
        roundtrip(m);
        roundtrip((1u32, "x".to_string(), Bytes(vec![9])));
    }

    #[test]
    fn varint_edge_cases() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader::new(&buf);
            assert_eq!(get_varint(&mut r).unwrap(), v);
            assert!(r.is_empty());
        }
    }

    #[test]
    fn truncated_input_errors() {
        let bytes = "hello".to_string().to_bytes();
        for cut in 0..bytes.len() {
            assert!(String::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut bytes = 7u32.to_bytes();
        bytes.push(0);
        assert!(u32::from_bytes(&bytes).is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        // A varint length far larger than the buffer must not allocate.
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX / 2);
        assert!(Bytes::from_bytes(&buf).is_err());
        assert!(Vec::<u64>::from_bytes(&buf).is_err());
    }

    #[test]
    fn invalid_bool_and_option_tags() {
        assert!(bool::from_bytes(&[2]).is_err());
        assert!(Option::<u8>::from_bytes(&[9, 1]).is_err());
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 2);
        buf.extend_from_slice(&[0xff, 0xfe]);
        assert!(String::from_bytes(&buf).is_err());
    }
}
