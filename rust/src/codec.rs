//! Binary serialization substrate (the stack's "pickle").
//!
//! The offline registry has no serde, so proxystore ships its own compact
//! little-endian codec: fixed-width primitives, LEB128 varint lengths, and
//! derive-free [`Encode`]/[`Decode`] traits implemented over the std
//! containers the stack uses. All wire formats (KV protocol, broker frames,
//! stream events, proxy factories, task payloads) are built from these
//! primitives, so a codec round-trip property test covers the whole stack's
//! framing.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::error::{Error, Result};

/// Serialize `self` onto the end of `buf`.
pub trait Encode {
    fn encode(&self, buf: &mut Vec<u8>);

    /// Convenience: encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode(&mut buf);
        buf
    }
}

/// Deserialize a value from a [`Reader`].
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self>;

    /// Convenience: decode from a complete buffer, requiring full consumption.
    fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut r = Reader::new(data);
        let v = Self::decode(&mut r)?;
        if !r.is_empty() {
            return Err(Error::Codec(format!(
                "{} trailing bytes after decode",
                r.remaining()
            )));
        }
        Ok(v)
    }

    /// Decode from an owned buffer. The default delegates to
    /// [`Decode::from_bytes`]; bulk types override it to reuse the
    /// allocation (e.g. [`Bytes`] shifts off its header in place), which
    /// is the zero-copy tail of proxy resolution on single-owner blobs.
    fn from_owned(data: Vec<u8>) -> Result<Self> {
        Self::from_bytes(&data)
    }
}

/// Cursor over a byte slice.
pub struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Reader { data, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Bytes consumed so far (the cursor offset into the input). Lets
    /// owned decoders ([`Decode::from_owned`] on [`Buf`], the KV
    /// client's response
    /// path) convert a borrowed parse position back into a window over
    /// the original allocation.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Take `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Codec(format!(
                "need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }
}

// --------------------------------------------------------------------------
// Varints (LEB128) for lengths and discriminants.
// --------------------------------------------------------------------------

/// Append a LEB128 varint.
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint.
pub fn get_varint(r: &mut Reader<'_>) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = r.take(1)?[0];
        if shift >= 64 {
            return Err(Error::Codec("varint overflow".into()));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn get_len(r: &mut Reader<'_>) -> Result<usize> {
    let v = get_varint(r)?;
    // Defensive cap: decoding never allocates more than the input could
    // plausibly describe (protects servers from hostile length prefixes).
    if v > (r.remaining() as u64).saturating_mul(8).saturating_add(1 << 20) {
        return Err(Error::Codec(format!("length {v} exceeds input")));
    }
    Ok(v as usize)
}

// --------------------------------------------------------------------------
// Primitive impls
// --------------------------------------------------------------------------

macro_rules! impl_fixed {
    ($($t:ty),*) => {$(
        impl Encode for $t {
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }
        }
        impl Decode for $t {
            fn decode(r: &mut Reader<'_>) -> Result<Self> {
                Ok(<$t>::from_le_bytes(r.take_array()?))
            }
        }
    )*};
}

impl_fixed!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Encode for usize {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, *self as u64);
    }
}
impl Decode for usize {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(get_varint(r)? as usize)
    }
}

impl Encode for bool {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.push(u8::from(*self));
    }
}
impl Decode for bool {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        match r.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(Error::Codec(format!("invalid bool byte {b}"))),
        }
    }
}

impl Encode for String {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        buf.extend_from_slice(self.as_bytes());
    }
}
impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let raw = r.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| Error::Codec(format!("invalid utf8: {e}")))
    }
}

impl Encode for &str {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        buf.extend_from_slice(self.as_bytes());
    }
}

/// Bulk byte payload with memcpy encoding (vs the element-wise `Vec<T>`).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Bytes(pub Vec<u8>);

impl Encode for Bytes {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.reserve(self.0.len() + 10);
        put_varint(buf, self.0.len() as u64);
        buf.extend_from_slice(&self.0);
    }

    fn to_bytes(&self) -> Vec<u8> {
        // Exact-capacity fast path: one allocation, one memcpy.
        let mut buf = Vec::with_capacity(self.0.len() + 10);
        self.encode(&mut buf);
        buf
    }
}
impl Decode for Bytes {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        Ok(Bytes(r.take(n)?.to_vec()))
    }

    fn from_owned(mut data: Vec<u8>) -> Result<Self> {
        // Validate the header, then shift it off in place (memmove, no
        // allocation) instead of copying the payload out.
        let header_len = {
            let mut r = Reader::new(&data);
            let n = get_len(&mut r)?;
            let h = data.len() - r.remaining();
            if r.remaining() != n {
                return Err(Error::Codec(format!(
                    "bytes payload {} != declared {n}",
                    r.remaining()
                )));
            }
            h
        };
        data.drain(..header_len);
        Ok(Bytes(data))
    }
}

/// Cheaply-clonable byte buffer: an `Arc`-backed allocation plus an
/// `(offset, len)` window into it. Cloning is a refcount bump; slicing
/// mints a narrower window over the same allocation. This is the value
/// currency of the zero-copy data plane: the KV engine stores `Buf`s,
/// responses carry them, and the event loop's scatter-gather outbox
/// writes them straight to the socket — the payload bytes are allocated
/// once (at `SET` decode or engine insert) and never copied again.
///
/// Wire format is identical to [`Bytes`] (varint length + raw bytes), so
/// the two interoperate frame-for-frame. The borrowed [`Decode::decode`]
/// path necessarily copies (it only sees a slice); the owned
/// [`Decode::from_owned`] path wraps the whole input allocation and
/// windows past the header — zero copy, zero memmove.
#[derive(Clone, Default)]
pub struct Buf {
    data: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl Buf {
    /// Wrap an owned vector (full window, no copy).
    pub fn from_vec(data: Vec<u8>) -> Buf {
        let len = data.len();
        Buf { data: Arc::new(data), off: 0, len }
    }

    /// Share an existing allocation (full window, refcount bump).
    pub fn from_arc(data: Arc<Vec<u8>>) -> Buf {
        let len = data.len();
        Buf { data, off: 0, len }
    }

    /// Window `data[off..off + len]`. Panics if the window exceeds the
    /// allocation — windows are always constructed from validated parse
    /// positions, so an out-of-range window is a logic error.
    pub fn window(data: Arc<Vec<u8>>, off: usize, len: usize) -> Buf {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= data.len()),
            "buf window {off}+{len} exceeds allocation {}",
            data.len()
        );
        Buf { data, off, len }
    }

    /// Sub-window relative to this window (refcount bump, no copy).
    /// Panics when the range exceeds this window, like slice indexing.
    pub fn slice(&self, off: usize, len: usize) -> Buf {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= self.len),
            "buf slice {off}+{len} exceeds window {}",
            self.len
        );
        Buf { data: self.data.clone(), off: self.off + off, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data[self.off..self.off + self.len]
    }

    /// Whether this window covers its whole backing allocation — the
    /// invariant under which [`Buf::to_blob`]/[`Buf::into_blob`] are
    /// free (engine-stored values always qualify; client-decoded
    /// windows over a frame body do not).
    pub fn is_full_window(&self) -> bool {
        self.off == 0 && self.len == self.data.len()
    }

    /// Shared-allocation view as a [`Blob`](crate::store::Blob):
    /// refcount bump when the window is the whole allocation, one copy
    /// otherwise.
    pub fn to_blob(&self) -> Arc<Vec<u8>> {
        if self.is_full_window() {
            self.data.clone()
        } else {
            Arc::new(self.as_slice().to_vec())
        }
    }

    /// Consuming [`Buf::to_blob`]: free for a full window; a sole-owner
    /// sub-window shifts down in place (memmove, no allocation); only a
    /// still-shared sub-window copies. A `Blob`'s whole allocation IS
    /// the value, so sub-windows cannot simply hand the Arc over.
    pub fn into_blob(self) -> Arc<Vec<u8>> {
        if self.is_full_window() {
            self.data
        } else {
            match Arc::try_unwrap(self.data) {
                // Sole owner: shift the window down in place (memmove,
                // no allocation) — same cost the pre-Buf decode paid.
                Ok(mut v) => {
                    v.drain(..self.off);
                    v.truncate(self.len);
                    Arc::new(v)
                }
                Err(shared) => {
                    Arc::new(shared[self.off..self.off + self.len].to_vec())
                }
            }
        }
    }

    /// Take the bytes as an owned `Vec`: no copy for a sole-owner full
    /// window, an in-place memmove for a sole-owner sub-window, one copy
    /// only when the allocation is still shared.
    pub fn into_vec(self) -> Vec<u8> {
        match Arc::try_unwrap(self.data) {
            Ok(mut v) => {
                if self.off > 0 {
                    v.drain(..self.off);
                }
                v.truncate(self.len);
                v
            }
            Err(shared) => shared[self.off..self.off + self.len].to_vec(),
        }
    }
}

impl std::ops::Deref for Buf {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Buf {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Buf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Windows can be huge (the whole point); keep Debug bounded.
        if self.len <= 32 {
            write!(f, "Buf({:?})", self.as_slice())
        } else {
            write!(
                f,
                "Buf(len={}, head={:?}..)",
                self.len,
                &self.as_slice()[..16]
            )
        }
    }
}

impl PartialEq for Buf {
    fn eq(&self, other: &Buf) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Buf {}

impl From<Vec<u8>> for Buf {
    fn from(v: Vec<u8>) -> Buf {
        Buf::from_vec(v)
    }
}

impl From<Bytes> for Buf {
    fn from(b: Bytes) -> Buf {
        Buf::from_vec(b.0)
    }
}

impl From<Buf> for Bytes {
    fn from(b: Buf) -> Bytes {
        Bytes(b.into_vec())
    }
}

impl Encode for Buf {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.reserve(self.len + 10);
        put_varint(buf, self.len as u64);
        buf.extend_from_slice(self.as_slice());
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.len + 10);
        self.encode(&mut buf);
        buf
    }
}

impl Decode for Buf {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        // Borrowed input: a copy is unavoidable here. The zero-copy
        // path is `from_owned` below.
        let n = get_len(r)?;
        Ok(Buf::from_vec(r.take(n)?.to_vec()))
    }

    fn from_owned(data: Vec<u8>) -> Result<Self> {
        // Validate the header, then window past it over the original
        // allocation: no copy, no memmove (unlike `Bytes::from_owned`,
        // which shifts the payload down).
        let (off, len) = {
            let mut r = Reader::new(&data);
            let n = get_len(&mut r)?;
            if r.remaining() != n {
                return Err(Error::Codec(format!(
                    "buf payload {} != declared {n}",
                    r.remaining()
                )));
            }
            (r.position(), n)
        };
        Ok(Buf::window(Arc::new(data), off, len))
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        for item in self {
            item.encode(buf);
        }
    }
}
impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let mut v = Vec::with_capacity(n.min(r.remaining()));
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            None => buf.push(0),
            Some(v) => {
                buf.push(1);
                v.encode(buf);
            }
        }
    }
}
impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        match r.take(1)?[0] {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            b => Err(Error::Codec(format!("invalid option tag {b}"))),
        }
    }
}

impl<K: Encode + Ord, V: Encode> Encode for BTreeMap<K, V> {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.len() as u64);
        for (k, v) in self {
            k.encode(buf);
            v.encode(buf);
        }
    }
}
impl<K: Decode + Ord, V: Decode> Decode for BTreeMap<K, V> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let mut m = BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

macro_rules! impl_tuple {
    ($($name:ident : $idx:tt),+) => {
        impl<$($name: Encode),+> Encode for ($($name,)+) {
            fn encode(&self, buf: &mut Vec<u8>) {
                $(self.$idx.encode(buf);)+
            }
        }
        impl<$($name: Decode),+> Decode for ($($name,)+) {
            fn decode(r: &mut Reader<'_>) -> Result<Self> {
                Ok(($($name::decode(r)?,)+))
            }
        }
    };
}

impl_tuple!(A: 0);
impl_tuple!(A: 0, B: 1);
impl_tuple!(A: 0, B: 1, C: 2);
impl_tuple!(A: 0, B: 1, C: 2, D: 3);

/// Encode a `Vec<f32>` as raw little-endian words (bulk numeric payloads;
/// 4 bytes/elem, memcpy on both sides for the PJRT buffer path).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct F32s(pub Vec<f32>);

impl Encode for F32s {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.reserve(self.0.len() * 4 + 10);
        put_varint(buf, self.0.len() as u64);
        // Safe, portable memcpy: chunk through to_le_bytes in bulk.
        for chunk in self.0.chunks(1024) {
            for v in chunk {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}
impl Decode for F32s {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = get_len(r)?;
        let raw = r.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(F32s(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        let back = T::from_bytes(&bytes).expect("decode");
        assert_eq!(v, back);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u64::MAX);
        roundtrip(i64::MIN);
        roundtrip(-1.5f32);
        roundtrip(f64::consts_check());
        roundtrip(true);
        roundtrip(false);
        roundtrip(usize::MAX);
    }

    trait ConstsCheck {
        fn consts_check() -> f64 {
            std::f64::consts::PI
        }
    }
    impl ConstsCheck for f64 {}

    #[test]
    fn strings_roundtrip() {
        roundtrip(String::new());
        roundtrip("hello world".to_string());
        roundtrip("ünïcødé 🎉".to_string());
    }

    #[test]
    fn containers_roundtrip() {
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(Vec::<String>::new());
        roundtrip(Some("x".to_string()));
        roundtrip(Option::<u64>::None);
        roundtrip(Bytes(vec![0u8, 1, 2, 255]));
        roundtrip(F32s(vec![1.0, -2.5, f32::MAX]));
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 1u64);
        m.insert("b".to_string(), 2u64);
        roundtrip(m);
        roundtrip((1u32, "x".to_string(), Bytes(vec![9])));
    }

    #[test]
    fn varint_edge_cases() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader::new(&buf);
            assert_eq!(get_varint(&mut r).unwrap(), v);
            assert!(r.is_empty());
        }
    }

    #[test]
    fn truncated_input_errors() {
        let bytes = "hello".to_string().to_bytes();
        for cut in 0..bytes.len() {
            assert!(String::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut bytes = 7u32.to_bytes();
        bytes.push(0);
        assert!(u32::from_bytes(&bytes).is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        // A varint length far larger than the buffer must not allocate.
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX / 2);
        assert!(Bytes::from_bytes(&buf).is_err());
        assert!(Vec::<u64>::from_bytes(&buf).is_err());
    }

    #[test]
    fn invalid_bool_and_option_tags() {
        assert!(bool::from_bytes(&[2]).is_err());
        assert!(Option::<u8>::from_bytes(&[9, 1]).is_err());
    }

    #[test]
    fn buf_roundtrips_at_size_edges() {
        // Empty, 1-byte, varint-length boundaries (127/128: 1→2 header
        // bytes), and a 64 MiB bulk object.
        for size in [0usize, 1, 127, 128, 16384, 64 << 20] {
            let payload: Vec<u8> =
                (0..size).map(|i| (i % 251) as u8).collect();
            let buf = Buf::from_vec(payload.clone());
            assert_eq!(buf.len(), size);
            assert_eq!(buf.is_empty(), size == 0);
            let wire = buf.to_bytes();
            // Wire-compatible with Bytes in both directions.
            assert_eq!(wire, Bytes(payload.clone()).to_bytes());
            let back = Buf::from_bytes(&wire).unwrap();
            assert_eq!(back, buf);
            assert_eq!(back.as_slice(), &payload[..]);
            let as_bytes = Bytes::from_bytes(&wire).unwrap();
            assert_eq!(as_bytes.0, payload);
        }
    }

    #[test]
    fn buf_windowing_and_slicing() {
        let data = Arc::new((0u8..100).collect::<Vec<u8>>());
        let whole = Buf::from_arc(data.clone());
        assert!(whole.is_full_window());
        // Window at the start, middle, end, and the empty end boundary.
        let head = Buf::window(data.clone(), 0, 10);
        let mid = whole.slice(40, 20);
        let tail = Buf::window(data.clone(), 90, 10);
        let empty_end = Buf::window(data.clone(), 100, 0);
        assert_eq!(head.as_slice(), &(0u8..10).collect::<Vec<u8>>()[..]);
        assert_eq!(mid.as_slice(), &(40u8..60).collect::<Vec<u8>>()[..]);
        assert_eq!(tail.as_slice(), &(90u8..100).collect::<Vec<u8>>()[..]);
        assert!(empty_end.is_empty() && !empty_end.is_full_window());
        // Slicing a window re-bases onto the same allocation.
        let sub = mid.slice(5, 5);
        assert_eq!(sub.as_slice(), &[45, 46, 47, 48, 49]);
        assert_eq!(Arc::strong_count(&data), 7, "no hidden copies");
        // A full-window blob is the same allocation, not a copy.
        let blob = whole.to_blob();
        assert!(Arc::ptr_eq(&blob, &data));
        // A sub-window blob is a (correct) copy.
        assert_eq!(*mid.to_blob(), (40u8..60).collect::<Vec<u8>>());
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn buf_window_past_end_panics() {
        let data = Arc::new(vec![0u8; 8]);
        let _ = Buf::window(data, 4, 5);
    }

    #[test]
    fn buf_from_owned_takes_zero_copy_tail() {
        // `Buf::from_owned` must window over the original allocation:
        // same backing pointer (shifted by the header), same capacity —
        // no realloc, no memmove.
        let payload = vec![7u8; 1 << 20];
        let wire = Bytes(payload.clone()).to_bytes();
        let wire_ptr = wire.as_ptr() as usize;
        let wire_cap = wire.capacity();
        let header = wire.len() - payload.len();
        let buf = Buf::from_owned(wire).unwrap();
        assert_eq!(buf.as_slice(), &payload[..]);
        assert_eq!(buf.as_ptr() as usize, wire_ptr + header);
        assert!(!buf.is_full_window());
        // The backing allocation is the untouched wire buffer.
        let backing = buf.data.clone();
        assert_eq!(backing.capacity(), wire_cap);
        assert_eq!(backing.as_ptr() as usize, wire_ptr);
    }

    #[test]
    fn bytes_from_owned_reuses_allocation() {
        // `Bytes::from_owned` shifts the header off in place: capacity
        // identity proves no reallocation happened.
        let payload = vec![3u8; 4096];
        let wire = Bytes(payload.clone()).to_bytes();
        let wire_cap = wire.capacity();
        let b = Bytes::from_owned(wire).unwrap();
        assert_eq!(b.0, payload);
        assert_eq!(b.0.capacity(), wire_cap, "must not realloc");
    }

    #[test]
    fn buf_into_vec_and_blob_ownership() {
        // Sole-owner full window: the vec moves out untouched.
        let v = vec![1u8, 2, 3];
        let ptr = v.as_ptr() as usize;
        let out = Buf::from_vec(v).into_vec();
        assert_eq!(out.as_ptr() as usize, ptr);
        assert_eq!(out, vec![1, 2, 3]);
        // Sole-owner sub-window: in-place shift, same allocation.
        let wire = Bytes(vec![9u8; 64]).to_bytes();
        let cap = wire.capacity();
        let out = Buf::from_owned(wire).unwrap().into_vec();
        assert_eq!(out, vec![9u8; 64]);
        assert_eq!(out.capacity(), cap);
        // Shared allocation: copies, leaving the other clone intact.
        let a = Buf::from_vec(vec![5u8; 16]);
        let b = a.clone();
        assert_eq!(b.into_vec(), vec![5u8; 16]);
        assert_eq!(a.as_slice(), &[5u8; 16]);
        assert!(Arc::ptr_eq(&a.to_blob(), &a.into_blob()));
    }

    #[test]
    fn buf_hostile_and_truncated_input() {
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX / 2);
        assert!(Buf::from_bytes(&buf).is_err());
        assert!(Buf::from_owned(buf).is_err());
        let wire = Bytes(vec![1, 2, 3]).to_bytes();
        assert!(Buf::from_owned(wire[..wire.len() - 1].to_vec()).is_err());
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 2);
        buf.extend_from_slice(&[0xff, 0xfe]);
        assert!(String::from_bytes(&buf).is_err());
    }
}
