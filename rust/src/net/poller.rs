//! Readiness poller + cross-thread waker over the raw epoll surface.
//!
//! [`Poller`] multiplexes any number of nonblocking fds on one blocking
//! `epoll_wait` call (level-triggered, so a handler may stop early and be
//! re-notified), and [`Waker`] is a nonblocking eventfd registered like
//! any other fd — writing to it from any thread unblocks the wait. On
//! non-Linux targets both constructors return a config error and the
//! builder falls back to threaded ingress; every caller goes through
//! [`Poller::new`], so nothing else needs a cfg.

use crate::error::Result;

/// One readiness notification, decoded from the raw event mask.
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered under.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or hangup flagged by the kernel. Readers should still drain
    /// the fd first — a peer can flush data and close in one action.
    pub error: bool,
}

#[cfg(target_os = "linux")]
mod imp {
    use std::io::{Read, Write};
    use std::os::fd::{AsRawFd, OwnedFd, RawFd};
    use std::time::Duration;

    use super::PollEvent;
    use crate::error::Result;
    use crate::net::sys;

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: OwnedFd,
    }

    fn interest(readable: bool, writable: bool) -> u32 {
        let mut events = 0;
        if readable {
            events |= sys::EPOLLIN | sys::EPOLLRDHUP;
        }
        if writable {
            events |= sys::EPOLLOUT;
        }
        events
    }

    impl Poller {
        pub fn new() -> Result<Poller> {
            Ok(Poller { epfd: sys::epoll_create()? })
        }

        /// Register `fd` under `token` with the given interest set.
        pub fn add(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            sys::epoll_control(
                self.epfd.as_raw_fd(),
                sys::EPOLL_CTL_ADD,
                fd,
                Some(sys::EpollEvent {
                    events: interest(readable, writable),
                    data: token,
                }),
            )?;
            Ok(())
        }

        /// Change an existing registration's interest set.
        pub fn modify(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            sys::epoll_control(
                self.epfd.as_raw_fd(),
                sys::EPOLL_CTL_MOD,
                fd,
                Some(sys::EpollEvent {
                    events: interest(readable, writable),
                    data: token,
                }),
            )?;
            Ok(())
        }

        /// Drop a registration (idempotent enough for teardown paths: a
        /// second delete errors and the caller ignores it).
        pub fn delete(&self, fd: RawFd) -> Result<()> {
            sys::epoll_control(
                self.epfd.as_raw_fd(),
                sys::EPOLL_CTL_DEL,
                fd,
                None,
            )?;
            Ok(())
        }

        /// Block until at least one fd is ready (or `timeout` elapses),
        /// filling `out` with the decoded notifications. A signal-
        /// interrupted wait returns an empty batch rather than an error.
        pub fn wait(
            &self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> Result<()> {
            out.clear();
            let timeout_ms = match timeout {
                None => -1,
                Some(t) => t.as_millis().min(i32::MAX as u128) as i32,
            };
            let mut buf =
                [sys::EpollEvent { events: 0, data: 0 }; Self::BATCH];
            let n = match sys::epoll_wait_events(
                self.epfd.as_raw_fd(),
                &mut buf,
                timeout_ms,
            ) {
                Ok(n) => n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    return Ok(());
                }
                Err(e) => return Err(e.into()),
            };
            for ev in &buf[..n] {
                // Copy fields out by value: the struct is packed on
                // x86-64, so references into it would be unaligned.
                let events = ev.events;
                let token = ev.data;
                out.push(PollEvent {
                    token,
                    readable: events & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0,
                    writable: events & sys::EPOLLOUT != 0,
                    error: events & (sys::EPOLLERR | sys::EPOLLHUP) != 0,
                });
            }
            Ok(())
        }

        /// Max readiness notifications decoded per wait call.
        const BATCH: usize = 256;
    }

    /// Cross-thread wakeup for a parked [`Poller::wait`]: a nonblocking
    /// eventfd whose counter the loop drains each time it fires.
    pub struct Waker {
        file: std::fs::File,
    }

    impl Waker {
        pub fn new() -> Result<Waker> {
            Ok(Waker { file: std::fs::File::from(sys::eventfd_create()?) })
        }

        /// The fd to register with the poller (read interest).
        pub fn fd(&self) -> RawFd {
            self.file.as_raw_fd()
        }

        /// Unblock the poller. Callable from any thread; failure means
        /// the counter is already non-zero (a wake is pending) — fine.
        pub fn wake(&self) {
            let _ = (&self.file).write_all(&1u64.to_ne_bytes());
        }

        /// Reset the counter so the next wake re-arms readiness.
        pub fn drain(&self) {
            let mut buf = [0u8; 8];
            let _ = (&self.file).read_exact(&mut buf);
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    use std::os::fd::RawFd;
    use std::time::Duration;

    use super::PollEvent;
    use crate::error::{Error, Result};

    fn unsupported<T>() -> Result<T> {
        Err(Error::Config(
            "event-driven ingress requires Linux epoll; \
             use Ingress::Threaded on this platform"
                .into(),
        ))
    }

    /// Stub poller for non-Linux targets: construction fails, so the
    /// other methods are unreachable.
    pub struct Poller;

    impl Poller {
        pub fn new() -> Result<Poller> {
            unsupported()
        }

        pub fn add(
            &self,
            _fd: RawFd,
            _token: u64,
            _readable: bool,
            _writable: bool,
        ) -> Result<()> {
            unsupported()
        }

        pub fn modify(
            &self,
            _fd: RawFd,
            _token: u64,
            _readable: bool,
            _writable: bool,
        ) -> Result<()> {
            unsupported()
        }

        pub fn delete(&self, _fd: RawFd) -> Result<()> {
            unsupported()
        }

        pub fn wait(
            &self,
            _out: &mut Vec<PollEvent>,
            _timeout: Option<Duration>,
        ) -> Result<()> {
            unsupported()
        }
    }

    /// Stub waker: construction fails alongside the poller.
    pub struct Waker;

    impl Waker {
        pub fn new() -> Result<Waker> {
            unsupported()
        }

        pub fn fd(&self) -> RawFd {
            -1
        }

        pub fn wake(&self) {}

        pub fn drain(&self) {}
    }
}

pub use imp::{Poller, Waker};

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::time::Duration;

    #[test]
    fn waker_unblocks_wait_from_another_thread() {
        let poller = Poller::new().unwrap();
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        poller.add(waker.fd(), 1, true, false).unwrap();
        let w = waker.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
        });
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 1);
        assert!(events[0].readable);
        waker.drain();
        h.join().unwrap();
        // Drained: an immediate wait times out instead of spinning on a
        // stale readiness (level-triggered would re-report otherwise).
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn socket_readability_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        // Nothing to read yet.
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty());
        client.write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));
        // Write interest on an unsaturated socket reports immediately.
        poller.modify(server.as_raw_fd(), 7, true, true).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable));
        poller.delete(server.as_raw_fd()).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn peer_close_reports_readable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 3, true, false).unwrap();
        drop(client);
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        // A hangup must surface as readable (read returns 0) so the
        // loop's normal read path observes EOF.
        assert!(events.iter().any(|e| e.token == 3 && e.readable));
    }
}
