//! Zero-dependency HTTP/1.1 admin plane served by the event-loop
//! reactor.
//!
//! [`AdminService`] is a [`Service`] with [`Framing::Http`]: each frame
//! the loop delivers is one complete request (head plus any
//! `Content-Length` body) and each reply is a full response written
//! verbatim — the same epoll loops that serve the data plane serve the
//! scrape endpoint, so observability costs no extra runtime machinery.
//!
//! Routes:
//!
//! - `GET /metrics` — Prometheus text exposition of the process registry
//!   ([`TelemetrySnapshot::render_prometheus`]).
//! - `GET /healthz` — liveness: always `200` while the loop answers.
//! - `GET /readyz` — readiness: `200` only while every registered
//!   [readiness probe](register_readiness) reports ready (the elastic
//!   fabric flips its probe false while a migration drains).
//! - `GET /conns` — live introspection: per-pool connection counts and
//!   every registered [info probe](register_probe) (watch registry
//!   sizes, shard membership, ...).
//! - `GET /trace` — the trace ring as Chrome trace-viewer JSON
//!   (loadable in Perfetto / `chrome://tracing`).
//! - `GET /slow` — the slow-op log as text.
//!
//! Probes live in process-global registries so any subsystem can expose
//! state without holding a reference to the admin service (which may not
//! even exist yet when the subsystem starts).

use std::net::SocketAddr;
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::metrics::telemetry;
use crate::net::event_loop::{
    ConnHandle, EventLoopPool, FrameOutcome, Framing, Service,
};

/// A readiness check: `true` = ready to serve.
pub type ReadinessProbe = Arc<dyn Fn() -> bool + Send + Sync>;

/// An introspection probe: renders one live-state line for `/conns`.
pub type InfoProbe = Arc<dyn Fn() -> String + Send + Sync>;

fn readiness_registry() -> &'static Mutex<Vec<(String, ReadinessProbe)>> {
    static REG: OnceLock<Mutex<Vec<(String, ReadinessProbe)>>> =
        OnceLock::new();
    REG.get_or_init(Default::default)
}

fn probe_registry() -> &'static Mutex<Vec<(String, InfoProbe)>> {
    static REG: OnceLock<Mutex<Vec<(String, InfoProbe)>>> = OnceLock::new();
    REG.get_or_init(Default::default)
}

/// Register (or replace) the named readiness probe consulted by
/// `/readyz`. Probes should be cheap and never block.
pub fn register_readiness(name: &str, probe: ReadinessProbe) {
    let mut reg = readiness_registry().lock().unwrap();
    if let Some(slot) = reg.iter_mut().find(|(n, _)| n == name) {
        slot.1 = probe;
    } else {
        reg.push((name.to_string(), probe));
    }
}

/// Drop the named readiness probe. Returns whether it was registered.
pub fn unregister_readiness(name: &str) -> bool {
    let mut reg = readiness_registry().lock().unwrap();
    let before = reg.len();
    reg.retain(|(n, _)| n != name);
    reg.len() != before
}

/// Names of readiness probes currently reporting not-ready.
pub fn not_ready() -> Vec<String> {
    readiness_registry()
        .lock()
        .unwrap()
        .iter()
        .filter(|(_, probe)| !probe())
        .map(|(n, _)| n.clone())
        .collect()
}

/// Register (or replace) the named introspection probe shown by
/// `/conns`.
pub fn register_probe(name: &str, probe: InfoProbe) {
    let mut reg = probe_registry().lock().unwrap();
    if let Some(slot) = reg.iter_mut().find(|(n, _)| n == name) {
        slot.1 = probe;
    } else {
        reg.push((name.to_string(), probe));
    }
}

/// Drop the named introspection probe. Returns whether it was registered.
pub fn unregister_probe(name: &str) -> bool {
    let mut reg = probe_registry().lock().unwrap();
    let before = reg.len();
    reg.retain(|(n, _)| n != name);
    reg.len() != before
}

/// Build one full HTTP/1.1 response (keep-alive, explicit
/// `Content-Length`).
fn respond(status: u16, reason: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status} {reason}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: keep-alive\r\n\
         \r\n\
         {body}",
        body.len()
    )
    .into_bytes()
}

fn text(status: u16, reason: &str, body: &str) -> Vec<u8> {
    respond(status, reason, "text/plain; charset=utf-8", body)
}

/// The admin-plane service: plug into an
/// [`EventLoopPool`](crate::net::EventLoopPool) (typically one loop) via
/// [`ServerBuilder::admin_addr`](crate::net::ServerBuilder::admin_addr).
pub struct AdminService {
    /// Which server this plane fronts (`kv`, `broker`) — shown in
    /// `/conns`.
    label: String,
    /// Live data-plane connection count, supplied by the owning server.
    data_conns: Option<Arc<dyn Fn() -> usize + Send + Sync>>,
}

impl AdminService {
    pub fn new(label: &str) -> AdminService {
        AdminService { label: label.to_string(), data_conns: None }
    }

    /// Attach the owning server's live connection counter.
    pub fn with_data_conns(
        mut self,
        f: Arc<dyn Fn() -> usize + Send + Sync>,
    ) -> AdminService {
        self.data_conns = Some(f);
        self
    }

    fn route(&self, path: &str) -> Vec<u8> {
        match path {
            "/metrics" => {
                let body = telemetry::snapshot().render_prometheus();
                respond(200, "OK", "text/plain; version=0.0.4", &body)
            }
            "/healthz" => text(200, "OK", "ok\n"),
            "/readyz" => {
                let blocked = not_ready();
                if blocked.is_empty() {
                    text(200, "OK", "ready\n")
                } else {
                    let body = format!("not ready: {}\n", blocked.join(", "));
                    text(503, "Service Unavailable", &body)
                }
            }
            "/conns" => {
                let mut body = String::new();
                if let Some(f) = &self.data_conns {
                    body.push_str(&format!(
                        "{}.connections {}\n",
                        self.label,
                        f()
                    ));
                }
                for (name, probe) in probe_registry().lock().unwrap().iter()
                {
                    body.push_str(&format!("{name} {}\n", probe()));
                }
                text(200, "OK", &body)
            }
            "/trace" => {
                let snap = telemetry::snapshot();
                let body = crate::metrics::cluster::chrome_trace_json(&[(
                    self.label.clone(),
                    snap,
                )]);
                respond(200, "OK", "application/json", &body)
            }
            "/slow" => {
                let mut body = String::new();
                for op in &telemetry::snapshot().slow_ops {
                    body.push_str(&format!(
                        "{} {}us op={} peer={} trace={:016x} span={:x}\n",
                        op.start_us,
                        op.dur_us,
                        op.op,
                        op.peer,
                        op.trace_id,
                        op.span_id,
                    ));
                }
                text(200, "OK", &body)
            }
            _ => text(404, "Not Found", "not found\n"),
        }
    }
}

/// Spawn the admin plane as its own single event loop beside a server's
/// data plane. Used by the `spawn*` paths when the builder carries an
/// [`admin_addr`](crate::net::ServerBuilder::admin_addr); `data_conns`
/// supplies the live data-plane connection count shown by `/conns`.
pub fn spawn_admin(
    addr: SocketAddr,
    label: &str,
    data_conns: Arc<dyn Fn() -> usize + Send + Sync>,
) -> Result<EventLoopPool> {
    let service =
        Arc::new(AdminService::new(label).with_data_conns(data_conns));
    // One loop and a small cap: scrapers are few and cheap; the data
    // plane keeps every other loop thread.
    EventLoopPool::spawn(addr, 1, 64, service, &format!("{label}-admin"))
}

/// Minimal blocking HTTP/1.1 GET against an admin endpoint. Returns
/// `(status, body)`. The admin plane answers keep-alive with an explicit
/// `Content-Length`, so this reads exactly one response and returns
/// without waiting for the server to close. Used by tests, the `obs`
/// CLI scenario, and CI smoke checks — not a general-purpose client.
pub fn http_get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    stream.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: admin\r\n\r\n").as_bytes(),
    )?;
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos + 4;
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(Error::Protocol(
                "admin closed before response head".into(),
            ));
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            Error::Protocol(format!("bad admin status line: {head:?}"))
        })?;
    let mut content_length = 0usize;
    for line in head.lines() {
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    while buf.len() < head_end + content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(Error::Protocol(
                "admin closed mid-body".into(),
            ));
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    let body = String::from_utf8_lossy(
        &buf[head_end..head_end + content_length],
    )
    .into_owned();
    Ok((status, body))
}

impl Service for AdminService {
    fn framing(&self) -> Framing {
        Framing::Http
    }

    fn on_frame(&self, _conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome {
        // The frame is one full request; only the request line matters.
        let head = match std::str::from_utf8(&body) {
            Ok(s) => s,
            Err(_) => return FrameOutcome::Close,
        };
        let mut parts = head.split_whitespace();
        let (method, target) = match (parts.next(), parts.next()) {
            (Some(m), Some(t)) => (m, t),
            _ => return FrameOutcome::Close,
        };
        if method != "GET" {
            return FrameOutcome::Reply(
                text(405, "Method Not Allowed", "only GET\n").into(),
            );
        }
        // Strip any query string; routes don't take parameters.
        let path = target.split('?').next().unwrap_or(target);
        FrameOutcome::Reply(self.route(path).into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readiness_registry_add_replace_remove() {
        let name = format!("test.ready.{}", std::process::id());
        register_readiness(&name, Arc::new(|| false));
        assert!(not_ready().contains(&name));
        register_readiness(&name, Arc::new(|| true));
        assert!(!not_ready().contains(&name));
        assert!(unregister_readiness(&name));
        assert!(!unregister_readiness(&name));
    }

    #[test]
    fn routes_cover_admin_surface() {
        let svc = AdminService::new("test")
            .with_data_conns(Arc::new(|| 3));
        let ok = String::from_utf8(svc.route("/healthz")).unwrap();
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(ok.contains("\r\n\r\nok\n"));
        let metrics = String::from_utf8(svc.route("/metrics")).unwrap();
        assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"));
        let conns = String::from_utf8(svc.route("/conns")).unwrap();
        assert!(conns.contains("test.connections 3"));
        let trace = String::from_utf8(svc.route("/trace")).unwrap();
        assert!(trace.contains("traceEvents"));
        let missing = String::from_utf8(svc.route("/nope")).unwrap();
        assert!(missing.starts_with("HTTP/1.1 404"));
    }

    #[test]
    fn readyz_reflects_probe_state() {
        let name = format!("test.readyz.{}", std::process::id());
        let svc = AdminService::new("test");
        register_readiness(&name, Arc::new(|| false));
        let resp = String::from_utf8(svc.route("/readyz")).unwrap();
        assert!(resp.starts_with("HTTP/1.1 503"), "resp: {resp}");
        assert!(resp.contains(&name));
        unregister_readiness(&name);
        let resp = String::from_utf8(svc.route("/readyz")).unwrap();
        // Other tests may have registered their own failing probes; only
        // assert ours no longer blocks.
        assert!(!resp.contains(&name));
    }

}
