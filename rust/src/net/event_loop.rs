//! Readiness-driven connection reactor: the C1M ingress.
//!
//! An [`EventLoopPool`] owns N event-loop threads multiplexing every
//! connection on a [`Poller`](crate::net::Poller) instead of a thread
//! each. Loop 0 owns the listener (registered for readiness — no accept
//! busy-wait) and deals accepted sockets round-robin across the loops;
//! each connection is a small state machine: incremental frame
//! reassembly on readable (partial length prefixes and split bodies are
//! just buffered bytes), and a per-connection segment [`Outbox`] flushed
//! once per readiness burst with scatter-gather `writev` — many small
//! replies coalesce into one owned tail segment while large [`Buf`]
//! payloads ride the queue by reference, so a 16 MiB GET reply costs one
//! header allocation and zero payload copies. Write interest is only
//! armed while a connection has unflushed bytes.
//!
//! Protocol behaviour plugs in through [`Service`]: one callback per
//! complete frame, returning a [`FrameOutcome`]. Fast ops reply inline
//! from the loop thread. Genuinely blocking ops (a `WaitGet` parked on a
//! missing key, a broker long-poll) return [`FrameOutcome::Deferred`] and
//! complete later through the connection's [`ConnHandle`] — the loop
//! buffers any frames that arrive meanwhile and replays them in order, so
//! the wire's FIFO contract holds while the loop thread never parks.
//! Out-of-band pushes (watch `Notify` frames) ride the same handle from
//! whatever thread fires them: the message lands in the loop's inbox, an
//! eventfd waker unblocks the poll, and the loop writes the frame — no
//! per-connection writer mutex anywhere.

use std::collections::{HashMap, VecDeque};
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::codec::Buf;
use crate::error::{Error, Result};
use crate::metrics::telemetry;
use crate::net::poller::{Poller, Waker};

/// Poller token of the accept listener (loop 0 only).
const LISTENER_TOKEN: u64 = 0;
/// Poller token of the loop's eventfd waker.
const WAKER_TOKEN: u64 = 1;
/// First connection id; ids are unique across the whole pool so a
/// service keyed by conn id never sees cross-loop collisions.
const FIRST_CONN: u64 = 2;

/// Frame-body size cap, matching the wire protocol's reader cap.
const MAX_FRAME: usize = 1 << 30;
/// Unflushed-write cap per connection: a peer that stops reading while
/// pushes accumulate is closed rather than growing the buffer forever
/// (the threaded ingress bounds the same hazard with a write timeout).
const WBUF_CAP: usize = 1 << 28;

/// Cached registry handles for the reactor's hot path.
struct NetMetrics {
    connections: Arc<telemetry::Gauge>,
    iter_us: Arc<telemetry::Histogram>,
    wakeups: Arc<telemetry::Counter>,
    accepted: Arc<telemetry::Counter>,
    rejected: Arc<telemetry::Counter>,
}

fn net_metrics() -> &'static NetMetrics {
    static M: OnceLock<NetMetrics> = OnceLock::new();
    M.get_or_init(|| NetMetrics {
        connections: telemetry::gauge("net.loop.connections"),
        iter_us: telemetry::histogram("net.loop.iter_us"),
        wakeups: telemetry::counter("net.loop.wakeups"),
        accepted: telemetry::counter("net.loop.accepted"),
        rejected: telemetry::counter("net.loop.rejected"),
    })
}

/// How a service's wire protocol delimits frames on the stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framing {
    /// `u32` little-endian length prefix before every body (the KV and
    /// broker protocols). Replies are prefixed by the loop.
    LengthPrefixed,
    /// HTTP/1.1 request framing: a frame is one request head up to the
    /// blank line plus an optional `Content-Length` body, delivered raw.
    /// Replies are written verbatim (the service emits full responses).
    Http,
}

/// Head-size cap for HTTP framing: a request line + headers beyond this
/// without a blank line is a protocol violation.
const MAX_HTTP_HEAD: usize = 16 * 1024;

/// One gather segment of an outbound [`WireFrame`].
pub enum FrameSeg {
    /// Frame-private bytes (headers, small bodies): moved into the
    /// outbox, never re-copied.
    Owned(Vec<u8>),
    /// A refcounted window over shared value bytes: queueing one is a
    /// refcount bump, and the payload leaves through `writev` straight
    /// from the cached allocation.
    Shared(Buf),
}

impl FrameSeg {
    fn as_slice(&self) -> &[u8] {
        match self {
            FrameSeg::Owned(v) => v,
            FrameSeg::Shared(b) => b.as_slice(),
        }
    }

    fn len(&self) -> usize {
        self.as_slice().len()
    }
}

/// An outbound frame as a segment list — the unit services hand the
/// loop. A flat `Vec<u8>` converts into one owned segment (`body.into()`
/// at legacy call sites); the zero-copy encode paths build
/// `[Owned(header), Shared(payload)]` frames so large values cross the
/// outbox by reference instead of by copy.
#[derive(Default)]
pub struct WireFrame {
    segs: Vec<FrameSeg>,
    len: usize,
}

impl WireFrame {
    pub fn new() -> WireFrame {
        WireFrame::default()
    }

    /// A single-segment frame owning `body` outright.
    pub fn from_vec(body: Vec<u8>) -> WireFrame {
        let mut f = WireFrame::new();
        f.push_owned(body);
        f
    }

    /// Append frame-private bytes (empty vectors are dropped).
    pub fn push_owned(&mut self, body: Vec<u8>) {
        if !body.is_empty() {
            self.len += body.len();
            self.segs.push(FrameSeg::Owned(body));
        }
    }

    /// Append a shared payload window (empty windows are dropped).
    pub fn push_shared(&mut self, payload: Buf) {
        if !payload.is_empty() {
            self.len += payload.len();
            self.segs.push(FrameSeg::Shared(payload));
        }
    }

    /// Total body length across all segments (what the length prefix
    /// advertises).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Flatten every segment into one contiguous body — the copy-mode
    /// baseline and test comparisons; the zero-copy data path never
    /// calls this.
    pub fn concat(&self) -> Vec<u8> {
        let mut flat = Vec::with_capacity(self.len);
        for seg in &self.segs {
            flat.extend_from_slice(seg.as_slice());
        }
        flat
    }
}

impl From<Vec<u8>> for WireFrame {
    fn from(body: Vec<u8>) -> WireFrame {
        WireFrame::from_vec(body)
    }
}

/// What the loop does with a completed inbound frame.
pub enum FrameOutcome {
    /// Write this reply frame (the loop adds the length prefix) in FIFO
    /// position.
    Reply(WireFrame),
    /// The service owns the reply: a helper thread will deliver it via
    /// [`ConnHandle::complete`]. Until then the loop buffers this
    /// connection's later frames and replays them in order — FIFO holds
    /// without parking the loop.
    Deferred,
    /// Write `reply`, then surrender the raw stream to `take` once the
    /// write buffer drains (subscribe push mode). `take` runs on the
    /// loop thread and must hand the stream to its own thread promptly.
    Handoff {
        reply: WireFrame,
        take: Box<dyn FnOnce(TcpStream) + Send>,
    },
    /// Protocol violation: drop the connection.
    Close,
}

/// Per-connection protocol logic plugged into the reactor.
pub trait Service: Send + Sync + 'static {
    /// A connection was registered with a loop.
    fn on_open(&self, conn: &ConnHandle) {
        let _ = conn;
    }

    /// One complete frame body arrived.
    fn on_frame(&self, conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome;

    /// Which wire framing this service speaks (cached per pool at spawn).
    fn framing(&self) -> Framing {
        Framing::LengthPrefixed
    }

    /// The connection left the loop (close or handoff): release anything
    /// keyed on its id. Pushes sent after this are silently dropped.
    fn on_close(&self, conn_id: u64) {
        let _ = conn_id;
    }
}

/// Cross-thread messages into a loop, drained after each poll wake.
enum LoopMsg {
    /// Out-of-band frame (watch `Notify`): written even mid-deferral.
    /// `lat` records fire-to-write latency into the given histogram.
    Push {
        conn: u64,
        frame: WireFrame,
        lat: Option<(Instant, Arc<telemetry::Histogram>)>,
    },
    /// FIFO reply finishing a [`FrameOutcome::Deferred`] op.
    Complete { conn: u64, frame: WireFrame },
    /// Force-close a connection.
    CloseConn { conn: u64 },
    /// A freshly accepted socket dealt over from the accepting loop.
    AddConn(TcpStream),
    /// Stop the loop and close everything it owns.
    Shutdown,
}

/// The half of a loop its producers share: inbox + waker.
struct LoopShared {
    waker: Waker,
    inbox: Mutex<Vec<LoopMsg>>,
}

impl LoopShared {
    fn send(&self, msg: LoopMsg) {
        self.inbox.lock().unwrap().push(msg);
        self.waker.wake();
    }
}

/// A service's handle to one connection, valid from any thread. Cheap to
/// clone; sends become no-ops once the connection is gone.
#[derive(Clone)]
pub struct ConnHandle {
    conn_id: u64,
    shared: Arc<LoopShared>,
}

impl ConnHandle {
    /// Pool-unique id of this connection (stable service-side key).
    pub fn conn_id(&self) -> u64 {
        self.conn_id
    }

    /// Queue an out-of-band frame (e.g. a watch `Notify`) and wake the
    /// loop. `lat` stamps fire-to-write latency into a histogram.
    pub fn push_frame(
        &self,
        frame: impl Into<WireFrame>,
        lat: Option<(Instant, Arc<telemetry::Histogram>)>,
    ) {
        self.shared.send(LoopMsg::Push {
            conn: self.conn_id,
            frame: frame.into(),
            lat,
        });
    }

    /// Deliver the FIFO reply of a deferred op; the loop then replays any
    /// frames it buffered behind it.
    pub fn complete(&self, frame: impl Into<WireFrame>) {
        self.shared.send(LoopMsg::Complete {
            conn: self.conn_id,
            frame: frame.into(),
        });
    }

    /// Ask the loop to drop this connection.
    pub fn close(&self) {
        self.shared.send(LoopMsg::CloseConn { conn: self.conn_id });
    }
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// Reassembly buffer: bytes read but not yet framed. `rpos` marks
    /// consumed frames (compacted lazily).
    rbuf: Vec<u8>,
    rpos: usize,
    /// Outbound segment queue: complete frames awaiting the socket.
    out: Outbox,
    /// Whether the poller registration currently includes write interest.
    writable_interest: bool,
    /// A deferred op is in flight; inbound frames queue in `backlog`.
    deferred: bool,
    backlog: VecDeque<Vec<u8>>,
    /// Pending stream handoff, executed once the outbox drains.
    handoff: Option<Box<dyn FnOnce(TcpStream) + Send>>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            rpos: 0,
            out: Outbox::new(),
            writable_interest: false,
            deferred: false,
            backlog: VecDeque::new(),
            handoff: None,
        }
    }
}

/// Owned segments at or under this size are memcpy'd into the outbox's
/// owned tail (coalescing many small frames into one gather entry, as
/// the flat write buffer always did); larger ones are queued by move.
const OWNED_INLINE_MAX: usize = 16 * 1024;

/// `Shared` segments at or under this size are copied into the owned
/// tail instead of occupying their own iovec slot — a sub-KiB memcpy is
/// cheaper than an extra gather entry. These are the only payload bytes
/// the outbox ever copies, and they are counted in `data.bytes_copied`.
const SHARED_INLINE_MAX: usize = 512;

/// Per-connection outbound segment queue, drained with `writev`.
struct Outbox {
    segs: VecDeque<FrameSeg>,
    /// Bytes of the front segment already written to the socket.
    front_pos: usize,
    /// Total unflushed bytes across every segment.
    len: usize,
}

impl Outbox {
    fn new() -> Outbox {
        Outbox { segs: VecDeque::new(), front_pos: 0, len: 0 }
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append raw bytes to the owned tail segment (creating one if the
    /// queue is empty or ends in a shared segment).
    fn extend_owned(&mut self, bytes: &[u8]) {
        if bytes.is_empty() {
            return;
        }
        self.len += bytes.len();
        if let Some(FrameSeg::Owned(tail)) = self.segs.back_mut() {
            tail.extend_from_slice(bytes);
        } else {
            self.segs.push_back(FrameSeg::Owned(bytes.to_vec()));
        }
    }

    /// Queue a frame under the pool's framing: length-prefixed protocols
    /// get the `u32` prefix first, HTTP responses go out verbatim. Small
    /// segments coalesce into the owned tail; large owned segments move
    /// in and large shared segments ride by reference.
    fn push_frame(&mut self, framing: Framing, frame: WireFrame) {
        if framing == Framing::LengthPrefixed {
            self.extend_owned(&(frame.len() as u32).to_le_bytes());
        }
        for seg in frame.segs {
            match seg {
                FrameSeg::Owned(v) if v.len() <= OWNED_INLINE_MAX => {
                    self.extend_owned(&v);
                }
                FrameSeg::Owned(v) => {
                    self.len += v.len();
                    self.segs.push_back(FrameSeg::Owned(v));
                }
                FrameSeg::Shared(b) if b.len() <= SHARED_INLINE_MAX => {
                    telemetry::data_metrics()
                        .bytes_copied
                        .add(b.len() as u64);
                    self.extend_owned(&b);
                }
                FrameSeg::Shared(b) => {
                    self.len += b.len();
                    self.segs.push_back(FrameSeg::Shared(b));
                }
            }
        }
    }

    /// Drop `n` freshly written bytes off the front of the queue.
    fn advance(&mut self, mut n: usize) {
        self.len -= n;
        while n > 0 {
            let left = self.segs.front().expect("advance past end").len()
                - self.front_pos;
            if n < left {
                self.front_pos += n;
                return;
            }
            n -= left;
            self.front_pos = 0;
            self.segs.pop_front();
        }
    }
}

/// Pop the next complete frame body, or `Ok(None)` if more bytes are
/// needed. `Err` is an oversized frame (protocol violation).
fn take_frame(
    conn: &mut Conn,
    framing: Framing,
) -> std::result::Result<Option<Vec<u8>>, ()> {
    if framing == Framing::Http {
        return take_http_frame(conn);
    }
    let avail = conn.rbuf.len() - conn.rpos;
    if avail < 4 {
        compact(conn);
        return Ok(None);
    }
    let len_bytes: [u8; 4] =
        conn.rbuf[conn.rpos..conn.rpos + 4].try_into().unwrap();
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(());
    }
    if avail < 4 + len {
        compact(conn);
        return Ok(None);
    }
    let body = conn.rbuf[conn.rpos + 4..conn.rpos + 4 + len].to_vec();
    conn.rpos += 4 + len;
    Ok(Some(body))
}

/// Pop one complete HTTP/1.1 request (head through blank line plus any
/// `Content-Length` body) as a raw frame.
fn take_http_frame(
    conn: &mut Conn,
) -> std::result::Result<Option<Vec<u8>>, ()> {
    let buf = &conn.rbuf[conn.rpos..];
    let Some(head_end) =
        buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
    else {
        if buf.len() > MAX_HTTP_HEAD {
            return Err(()); // unbounded header section
        }
        compact(conn);
        return Ok(None);
    };
    if head_end > MAX_HTTP_HEAD {
        return Err(());
    }
    let head = &buf[..head_end];
    let mut body_len = 0usize;
    for line in head.split(|&b| b == b'\n') {
        let Ok(line) = std::str::from_utf8(line) else { continue };
        let Some((name, value)) = line.split_once(':') else { continue };
        if name.trim().eq_ignore_ascii_case("content-length") {
            body_len = value.trim().parse().map_err(|_| ())?;
        }
    }
    if body_len > MAX_FRAME {
        return Err(());
    }
    let total = head_end + body_len;
    if buf.len() < total {
        compact(conn);
        return Ok(None);
    }
    let frame = buf[..total].to_vec();
    conn.rpos += total;
    Ok(Some(frame))
}

/// Reclaim consumed reassembly bytes once they dominate the buffer.
fn compact(conn: &mut Conn) {
    if conn.rpos == conn.rbuf.len() {
        conn.rbuf.clear();
        conn.rpos = 0;
    } else if conn.rpos > (1 << 16) {
        conn.rbuf.drain(..conn.rpos);
        conn.rpos = 0;
    }
}

enum FlushResult {
    Drained,
    Partial,
    Dead,
}

/// One gather write against the socket: `writev` over the live segments
/// on Linux (up to [`IOV_MAX_BATCH`](crate::net::sys::IOV_MAX_BATCH)
/// per call), a single-segment `write` elsewhere. Returns bytes written.
fn write_once(stream: &mut TcpStream, out: &Outbox) -> std::io::Result<usize> {
    #[cfg(target_os = "linux")]
    {
        use crate::net::sys;
        let mut iov: Vec<sys::IoVec> =
            Vec::with_capacity(out.segs.len().min(sys::IOV_MAX_BATCH));
        for (i, seg) in out.segs.iter().take(sys::IOV_MAX_BATCH).enumerate()
        {
            let bytes = seg.as_slice();
            let bytes = if i == 0 { &bytes[out.front_pos..] } else { bytes };
            iov.push(sys::IoVec { base: bytes.as_ptr(), len: bytes.len() });
        }
        sys::writev_segments(stream.as_raw_fd(), &iov)
    }
    #[cfg(not(target_os = "linux"))]
    {
        use std::io::Write;
        let front = out.segs.front().expect("write_once on empty outbox");
        stream.write(&front.as_slice()[out.front_pos..])
    }
}

fn flush_outbox(conn: &mut Conn) -> FlushResult {
    while !conn.out.is_empty() {
        match write_once(&mut conn.stream, &conn.out) {
            Ok(0) => return FlushResult::Dead,
            Ok(n) => conn.out.advance(n),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return FlushResult::Partial;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return FlushResult::Dead,
        }
    }
    FlushResult::Drained
}

struct EventLoop<S: Service> {
    poller: Poller,
    shared: Arc<LoopShared>,
    /// Loop 0 owns the listener; the others only receive dealt sockets.
    listener: Option<TcpListener>,
    peers: Vec<Arc<LoopShared>>,
    next_peer: usize,
    conns: HashMap<u64, Conn>,
    ids: Arc<AtomicU64>,
    service: Arc<S>,
    conn_count: Arc<AtomicUsize>,
    max_connections: usize,
    framing: Framing,
    scratch: Vec<u8>,
    stop: bool,
}

impl<S: Service> EventLoop<S> {
    fn handle(&self, id: u64) -> ConnHandle {
        ConnHandle { conn_id: id, shared: self.shared.clone() }
    }

    fn run(mut self) {
        let m = net_metrics();
        if self
            .poller
            .add(self.shared.waker.fd(), WAKER_TOKEN, true, false)
            .is_err()
        {
            return;
        }
        if let Some(listener) = &self.listener {
            if self
                .poller
                .add(listener.as_raw_fd(), LISTENER_TOKEN, true, false)
                .is_err()
            {
                return;
            }
        }
        let mut events = Vec::new();
        while !self.stop {
            if self.poller.wait(&mut events, None).is_err() {
                break;
            }
            let busy = Instant::now();
            m.wakeups.incr();
            for ev in &events {
                match ev.token {
                    WAKER_TOKEN => self.shared.waker.drain(),
                    LISTENER_TOKEN => self.accept_ready(),
                    id => {
                        self.conn_ready(id, ev.readable, ev.writable, ev.error)
                    }
                }
            }
            self.drain_inbox();
            m.iter_us.record_duration(busy.elapsed());
        }
        self.teardown();
    }

    fn accept_ready(&mut self) {
        let m = net_metrics();
        loop {
            let accepted = match &self.listener {
                Some(listener) => listener.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => {
                    if self.max_connections > 0
                        && self.conn_count.load(Ordering::Relaxed)
                            >= self.max_connections
                    {
                        m.rejected.incr();
                        continue; // drop: over the configured cap
                    }
                    self.conn_count.fetch_add(1, Ordering::Relaxed);
                    m.accepted.incr();
                    let idx = self.next_peer;
                    self.next_peer = (self.next_peer + 1) % self.peers.len();
                    if Arc::ptr_eq(&self.peers[idx], &self.shared) {
                        self.register_conn(stream);
                    } else {
                        self.peers[idx].send(LoopMsg::AddConn(stream));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
    }

    fn register_conn(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err()
            || stream.set_nodelay(true).is_err()
        {
            self.conn_count.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let id = self.ids.fetch_add(1, Ordering::Relaxed);
        if self.poller.add(stream.as_raw_fd(), id, true, false).is_err() {
            self.conn_count.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        net_metrics().connections.add(1);
        self.conns.insert(id, Conn::new(stream));
        self.service.on_open(&self.handle(id));
    }

    fn conn_ready(&mut self, id: u64, readable: bool, writable: bool, error: bool) {
        if !self.conns.contains_key(&id) {
            return;
        }
        if writable && !self.try_flush(id) {
            return;
        }
        if readable {
            if !self.read_ready(id) {
                return;
            }
            self.try_flush(id);
        } else if error {
            // Pure error notification (no data pending): drop it.
            self.close_conn(id);
        }
    }

    /// Drain the socket, frame, dispatch. Returns false once closed.
    fn read_ready(&mut self, id: u64) -> bool {
        loop {
            let Some(conn) = self.conns.get_mut(&id) else { return false };
            match conn.stream.read(&mut self.scratch) {
                Ok(0) => {
                    self.close_conn(id);
                    return false;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&self.scratch[..n]);
                    if n < self.scratch.len() {
                        break; // likely drained; level-trigger re-reports
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.close_conn(id);
                    return false;
                }
            }
        }
        loop {
            let parked = {
                let Some(conn) = self.conns.get_mut(&id) else {
                    return false;
                };
                conn.deferred || conn.handoff.is_some()
            };
            let frame = {
                let conn = self.conns.get_mut(&id).unwrap();
                take_frame(conn, self.framing)
            };
            match frame {
                Ok(Some(body)) if parked => {
                    // A deferred reply is pending: preserve FIFO by
                    // queueing; `complete_conn` replays in order.
                    let conn = self.conns.get_mut(&id).unwrap();
                    conn.backlog.push_back(body);
                }
                Ok(Some(body)) => {
                    if !self.dispatch(id, body) {
                        self.close_conn(id);
                        return false;
                    }
                }
                Ok(None) => break,
                Err(()) => {
                    self.close_conn(id);
                    return false;
                }
            }
        }
        true
    }

    /// Run one frame through the service. Returns false to close.
    fn dispatch(&mut self, id: u64, body: Vec<u8>) -> bool {
        let handle = self.handle(id);
        let service = self.service.clone();
        match service.on_frame(&handle, body) {
            FrameOutcome::Reply(frame) => {
                if let Some(conn) = self.conns.get_mut(&id) {
                    conn.out.push_frame(self.framing, frame);
                }
                true
            }
            FrameOutcome::Deferred => {
                if let Some(conn) = self.conns.get_mut(&id) {
                    conn.deferred = true;
                }
                true
            }
            FrameOutcome::Handoff { reply, take } => {
                if let Some(conn) = self.conns.get_mut(&id) {
                    conn.out.push_frame(self.framing, reply);
                    conn.handoff = Some(take);
                }
                true
            }
            FrameOutcome::Close => false,
        }
    }

    /// Write as much of the buffered output as the socket accepts,
    /// managing write interest. Returns false once the conn left the map.
    fn try_flush(&mut self, id: u64) -> bool {
        let result = {
            let Some(conn) = self.conns.get_mut(&id) else { return false };
            flush_outbox(conn)
        };
        match result {
            FlushResult::Dead => {
                self.close_conn(id);
                false
            }
            FlushResult::Drained => {
                let (has_handoff, clear_interest, fd) = {
                    let conn = self.conns.get_mut(&id).unwrap();
                    (
                        conn.handoff.is_some(),
                        conn.writable_interest,
                        conn.stream.as_raw_fd(),
                    )
                };
                if has_handoff {
                    self.finish_handoff(id);
                    return false;
                }
                if clear_interest {
                    let _ = self.poller.modify(fd, id, true, false);
                    self.conns.get_mut(&id).unwrap().writable_interest = false;
                }
                true
            }
            FlushResult::Partial => {
                let conn = self.conns.get_mut(&id).unwrap();
                if conn.out.len > WBUF_CAP {
                    // Peer stopped reading with pushes still accumulating.
                    self.close_conn(id);
                    return false;
                }
                if !conn.writable_interest {
                    conn.writable_interest = true;
                    let fd = conn.stream.as_raw_fd();
                    let _ = self.poller.modify(fd, id, true, true);
                }
                true
            }
        }
    }

    /// Surrender a drained connection's stream to its handoff closure.
    fn finish_handoff(&mut self, id: u64) {
        let Some(mut conn) = self.conns.remove(&id) else { return };
        let _ = self.poller.delete(conn.stream.as_raw_fd());
        net_metrics().connections.add(-1);
        self.conn_count.fetch_sub(1, Ordering::Relaxed);
        self.service.on_close(id);
        let take = conn.handoff.take().expect("handoff set");
        let _ = conn.stream.set_nonblocking(false);
        take(conn.stream);
    }

    fn close_conn(&mut self, id: u64) {
        let Some(conn) = self.conns.remove(&id) else { return };
        let _ = self.poller.delete(conn.stream.as_raw_fd());
        let _ = conn.stream.shutdown(Shutdown::Both);
        net_metrics().connections.add(-1);
        self.conn_count.fetch_sub(1, Ordering::Relaxed);
        self.service.on_close(id);
    }

    fn drain_inbox(&mut self) {
        let msgs = std::mem::take(&mut *self.shared.inbox.lock().unwrap());
        if msgs.is_empty() {
            return;
        }
        let mut touched: Vec<u64> = Vec::new();
        for msg in msgs {
            match msg {
                LoopMsg::Push { conn, frame, lat } => {
                    if let Some(c) = self.conns.get_mut(&conn) {
                        c.out.push_frame(self.framing, frame);
                        if let Some((fired, hist)) = lat {
                            hist.record_duration(fired.elapsed());
                        }
                        touched.push(conn);
                    }
                }
                LoopMsg::Complete { conn, frame } => {
                    if self.conns.contains_key(&conn) {
                        self.complete_conn(conn, frame);
                        touched.push(conn);
                    }
                }
                LoopMsg::CloseConn { conn } => self.close_conn(conn),
                LoopMsg::AddConn(stream) => self.register_conn(stream),
                LoopMsg::Shutdown => self.stop = true,
            }
        }
        // One flush per touched connection, not per message: pushes that
        // landed together leave in one write.
        touched.sort_unstable();
        touched.dedup();
        for id in touched {
            self.try_flush(id);
        }
    }

    /// Finish a deferred op, then replay buffered frames in FIFO order
    /// until the backlog empties or another op defers.
    fn complete_conn(&mut self, id: u64, frame: WireFrame) {
        {
            let Some(conn) = self.conns.get_mut(&id) else { return };
            if !conn.deferred {
                return; // stale completion (conn was reused logic-side)
            }
            conn.out.push_frame(self.framing, frame);
            conn.deferred = false;
        }
        loop {
            let next = {
                let Some(conn) = self.conns.get_mut(&id) else { return };
                if conn.deferred || conn.handoff.is_some() {
                    return;
                }
                match conn.backlog.pop_front() {
                    Some(b) => b,
                    None => return,
                }
            };
            if !self.dispatch(id, next) {
                self.close_conn(id);
                return;
            }
        }
    }

    fn teardown(&mut self) {
        self.listener = None;
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            self.close_conn(id);
        }
    }
}

struct LoopHandle {
    shared: Arc<LoopShared>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// A running reactor: N loops, one listener, one [`Service`].
/// Dropping the pool shuts it down.
pub struct EventLoopPool {
    pub addr: SocketAddr,
    loops: Vec<LoopHandle>,
    conn_count: Arc<AtomicUsize>,
}

impl EventLoopPool {
    /// Bind `bind` and start `loops` event-loop threads serving
    /// `service`. `max_connections` of 0 means unlimited. Fails up front
    /// on non-Linux targets (no poller) — callers fall back to threaded
    /// ingress.
    pub fn spawn<S: Service>(
        bind: SocketAddr,
        loops: usize,
        max_connections: usize,
        service: Arc<S>,
        name: &str,
    ) -> Result<EventLoopPool> {
        let loops = loops.max(1);
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        // Build every poller/waker before spawning any thread, so a
        // constructor error (or a non-Linux target) fails cleanly.
        let mut parts = Vec::with_capacity(loops);
        for _ in 0..loops {
            let poller = Poller::new()?;
            let waker = Waker::new()?;
            poller.add(waker.fd(), WAKER_TOKEN, true, false)?;
            let shared =
                Arc::new(LoopShared { waker, inbox: Mutex::new(Vec::new()) });
            parts.push((poller, shared));
        }
        let peers: Vec<_> = parts.iter().map(|(_, s)| s.clone()).collect();
        let ids = Arc::new(AtomicU64::new(FIRST_CONN));
        let conn_count = Arc::new(AtomicUsize::new(0));
        let framing = service.framing();
        let mut handles: Vec<LoopHandle> = Vec::with_capacity(loops);
        let mut listener = Some(listener);
        for (i, (poller, shared)) in parts.into_iter().enumerate() {
            let el = EventLoop {
                poller,
                shared: shared.clone(),
                listener: if i == 0 { listener.take() } else { None },
                peers: peers.clone(),
                next_peer: 0,
                conns: HashMap::new(),
                ids: ids.clone(),
                service: service.clone(),
                conn_count: conn_count.clone(),
                max_connections,
                framing,
                scratch: vec![0; 1 << 16],
                stop: false,
            };
            let spawned = std::thread::Builder::new()
                .name(format!("{name}-loop-{i}"))
                .spawn(move || el.run());
            match spawned {
                Ok(thread) => {
                    handles.push(LoopHandle { shared, thread: Some(thread) })
                }
                Err(e) => {
                    for h in &handles {
                        h.shared.send(LoopMsg::Shutdown);
                    }
                    for h in &mut handles {
                        if let Some(t) = h.thread.take() {
                            let _ = t.join();
                        }
                    }
                    return Err(Error::Task(format!(
                        "spawn event loop thread: {e}"
                    )));
                }
            }
        }
        Ok(EventLoopPool { addr, loops: handles, conn_count })
    }

    /// Connections currently registered across all loops (diagnostics).
    pub fn connections(&self) -> usize {
        self.conn_count.load(Ordering::Relaxed)
    }

    /// Stop every loop and join its thread; all connections are closed.
    pub fn shutdown(&mut self) {
        for h in &self.loops {
            h.shared.send(LoopMsg::Shutdown);
        }
        for h in &mut self.loops {
            if let Some(t) = h.thread.take() {
                let _ = t.join();
            }
        }
    }
}

impl Drop for EventLoopPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::time::Duration;

    fn push_wire_frame(wire: &mut Vec<u8>, body: &[u8]) {
        wire.extend_from_slice(&(body.len() as u32).to_le_bytes());
        wire.extend_from_slice(body);
    }

    fn write_raw_frame(s: &mut TcpStream, body: &[u8]) {
        s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        s.write_all(body).unwrap();
    }

    fn read_raw_frame(s: &mut TcpStream) -> Vec<u8> {
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut body).unwrap();
        body
    }

    struct Echo;

    impl Service for Echo {
        fn on_frame(&self, _conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome {
            FrameOutcome::Reply(body.into())
        }
    }

    #[test]
    fn echo_roundtrip_and_pipelined_burst() {
        let mut pool = EventLoopPool::spawn(
            "127.0.0.1:0".parse().unwrap(),
            2,
            0,
            Arc::new(Echo),
            "echo",
        )
        .unwrap();
        let mut c = TcpStream::connect(pool.addr).unwrap();
        write_raw_frame(&mut c, b"hello");
        assert_eq!(read_raw_frame(&mut c), b"hello");
        // A burst of frames in one write comes back in order.
        let mut burst = Vec::new();
        for i in 0..100u8 {
            push_wire_frame(&mut burst, &[i, i, i]);
        }
        c.write_all(&burst).unwrap();
        for i in 0..100u8 {
            assert_eq!(read_raw_frame(&mut c), vec![i, i, i]);
        }
        pool.shutdown();
    }

    #[test]
    fn partial_frames_reassemble_across_reads() {
        let pool = EventLoopPool::spawn(
            "127.0.0.1:0".parse().unwrap(),
            1,
            0,
            Arc::new(Echo),
            "echo",
        )
        .unwrap();
        let mut c = TcpStream::connect(pool.addr).unwrap();
        let body = vec![7u8; 1000];
        let mut wire = Vec::new();
        push_wire_frame(&mut wire, &body);
        // Dribble the frame a few bytes at a time with pauses, so the
        // loop sees many partial reads (split length prefix included).
        for chunk in wire.chunks(3) {
            c.write_all(chunk).unwrap();
            c.flush().unwrap();
            std::thread::sleep(Duration::from_micros(200));
        }
        assert_eq!(read_raw_frame(&mut c), body);
    }

    struct DeferOdd;

    impl Service for DeferOdd {
        fn on_frame(&self, conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome {
            if body[0] % 2 == 1 {
                let handle = conn.clone();
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(20));
                    handle.complete(vec![100, 0]);
                });
                return FrameOutcome::Deferred;
            }
            FrameOutcome::Reply(body.into())
        }
    }

    #[test]
    fn deferred_ops_keep_fifo_order() {
        let pool = EventLoopPool::spawn(
            "127.0.0.1:0".parse().unwrap(),
            1,
            0,
            Arc::new(DeferOdd),
            "defer",
        )
        .unwrap();
        let mut c = TcpStream::connect(pool.addr).unwrap();
        // odd (deferred), then evens that must queue behind it.
        write_raw_frame(&mut c, &[1, 0]);
        write_raw_frame(&mut c, &[2, 0]);
        write_raw_frame(&mut c, &[4, 0]);
        assert_eq!(read_raw_frame(&mut c)[0], 100, "deferred reply first");
        assert_eq!(read_raw_frame(&mut c)[0], 2);
        assert_eq!(read_raw_frame(&mut c)[0], 4);
    }

    /// Echoes each body as a two-segment frame: the first half owned,
    /// the second half a `Shared` window — so the test exercises both
    /// the inline-coalescing path (small shared tails) and the iovec
    /// path (large shared payloads spanning partial `writev` flushes).
    struct SegEcho;

    impl Service for SegEcho {
        fn on_frame(&self, _conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome {
            let mid = body.len() / 2;
            let mut f = WireFrame::new();
            f.push_owned(body[..mid].to_vec());
            f.push_shared(Buf::from_vec(body[mid..].to_vec()));
            FrameOutcome::Reply(f)
        }
    }

    #[test]
    fn multi_segment_replies_preserve_bytes_and_order() {
        let pool = EventLoopPool::spawn(
            "127.0.0.1:0".parse().unwrap(),
            1,
            0,
            Arc::new(SegEcho),
            "seg-echo",
        )
        .unwrap();
        let mut c = TcpStream::connect(pool.addr).unwrap();
        // Sizes straddling the empty frame, the shared-inline threshold,
        // and a payload big enough to force partial writev flushes.
        for len in [0usize, 1, 9, 1023, 4096, 4 << 20] {
            let body: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
            write_raw_frame(&mut c, &body);
            assert_eq!(read_raw_frame(&mut c), body, "len={len}");
        }
        // A pipelined burst of multi-segment replies (shared halves above
        // the inline threshold, so segments interleave) stays in order.
        let mut burst = Vec::new();
        for i in 0..50u8 {
            push_wire_frame(&mut burst, &[i; 1200]);
        }
        c.write_all(&burst).unwrap();
        for i in 0..50u8 {
            assert_eq!(read_raw_frame(&mut c), vec![i; 1200]);
        }
    }

    #[test]
    fn max_connections_drops_excess() {
        let pool = EventLoopPool::spawn(
            "127.0.0.1:0".parse().unwrap(),
            1,
            2,
            Arc::new(Echo),
            "capped",
        )
        .unwrap();
        let mut keep: Vec<TcpStream> = Vec::new();
        for _ in 0..2 {
            let mut c = TcpStream::connect(pool.addr).unwrap();
            write_raw_frame(&mut c, b"ok");
            assert_eq!(read_raw_frame(&mut c), b"ok");
            keep.push(c);
        }
        // Third connection is dropped by the loop: reads see EOF.
        let mut extra = TcpStream::connect(pool.addr).unwrap();
        extra
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        write_raw_frame(&mut extra, b"nope");
        let mut buf = [0u8; 4];
        match extra.read(&mut buf) {
            Ok(0) => {}
            Ok(_) => panic!("capped connection must not be served"),
            Err(_) => {} // reset also acceptable
        }
        assert_eq!(pool.connections(), 2);
    }

    #[test]
    fn client_dying_mid_frame_closes_cleanly() {
        let pool = EventLoopPool::spawn(
            "127.0.0.1:0".parse().unwrap(),
            1,
            0,
            Arc::new(Echo),
            "echo",
        )
        .unwrap();
        {
            let mut c = TcpStream::connect(pool.addr).unwrap();
            // Announce 100 bytes, send 3, die.
            c.write_all(&100u32.to_le_bytes()).unwrap();
            c.write_all(&[1, 2, 3]).unwrap();
        }
        // The loop reaps the connection; a new client is unaffected.
        let deadline = Instant::now() + Duration::from_secs(5);
        while pool.connections() > 0 {
            assert!(Instant::now() < deadline, "dead conn not reaped");
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut c = TcpStream::connect(pool.addr).unwrap();
        write_raw_frame(&mut c, b"after");
        assert_eq!(read_raw_frame(&mut c), b"after");
    }
}
