//! Unified server construction: one builder for every server in the
//! crate instead of a zoo of `spawn`/`spawn_with_state` constructors.
//!
//! ```no_run
//! use proxystore::kv::KvState;
//! use proxystore::net::{Ingress, ServerBuilder};
//!
//! // Default ingress (event loop on Linux), ephemeral port:
//! let server = ServerBuilder::new().spawn_kv().unwrap();
//!
//! // Explicit everything, sharing pre-built state:
//! let state = KvState::new();
//! let server = ServerBuilder::new()
//!     .ingress(Ingress::Threaded)
//!     .bind("127.0.0.1:0".parse().unwrap())
//!     .max_connections(10_000)
//!     .with_state(state)
//!     .spawn()
//!     .unwrap();
//! # let _ = server;
//! ```
//!
//! The generic `state` slot is what lets one builder serve both servers:
//! `with_state(KvState)` steers `spawn()` to a KV server,
//! `with_state(BrokerState)` to a broker, and the stateless
//! `spawn_kv()`/`spawn_broker()` shorthands cover the common
//! fresh-state case. The `spawn*` impls live next to each server.

use std::net::SocketAddr;
use std::path::Path;

use crate::persist::DurabilityOptions;

/// How a server accepts and serves connections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ingress {
    /// One OS thread per connection, blocking I/O. Simple, portable,
    /// and fine up to a few hundred connections.
    Threaded,
    /// A small pool of epoll event loops multiplexing every connection
    /// (Linux only): bounded threads regardless of connection count.
    EventLoop,
}

impl Default for Ingress {
    fn default() -> Ingress {
        if cfg!(target_os = "linux") {
            Ingress::EventLoop
        } else {
            Ingress::Threaded
        }
    }
}

/// Placeholder state for a builder that hasn't been given any: `spawn_kv`
/// / `spawn_broker` build fresh state themselves.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoState;

/// Unified configuration for spawning a server; see the module docs.
#[derive(Debug, Clone)]
pub struct ServerBuilder<S = NoState> {
    pub(crate) ingress: Ingress,
    pub(crate) bind: SocketAddr,
    pub(crate) max_connections: usize,
    pub(crate) event_loops: usize,
    pub(crate) admin: Option<SocketAddr>,
    pub(crate) durability: Option<DurabilityOptions>,
    pub(crate) zero_copy: bool,
    pub(crate) state: S,
}

fn default_event_loops() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4)
}

impl ServerBuilder<NoState> {
    pub fn new() -> ServerBuilder<NoState> {
        ServerBuilder {
            ingress: Ingress::default(),
            bind: SocketAddr::from(([127, 0, 0, 1], 0)),
            max_connections: 0,
            event_loops: default_event_loops(),
            admin: None,
            durability: None,
            zero_copy: true,
            state: NoState,
        }
    }
}

impl Default for ServerBuilder<NoState> {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl<S> ServerBuilder<S> {
    /// Select the ingress mode (default: event loop on Linux, threaded
    /// elsewhere).
    pub fn ingress(mut self, ingress: Ingress) -> Self {
        self.ingress = ingress;
        self
    }

    /// Listen address (default `127.0.0.1:0` — an ephemeral port).
    pub fn bind(mut self, addr: SocketAddr) -> Self {
        self.bind = addr;
        self
    }

    /// Cap concurrent connections; `0` (the default) means unlimited.
    /// Excess connections are dropped at accept.
    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n;
        self
    }

    /// Number of event-loop threads (event ingress only; default
    /// `min(cores, 4)`, floored at 1).
    pub fn event_loops(mut self, n: usize) -> Self {
        self.event_loops = n.max(1);
        self
    }

    /// Also serve the HTTP admin plane ([`crate::net::http`]) on this
    /// address: `/metrics` (Prometheus exposition), `/healthz`, `/readyz`,
    /// `/conns`, `/trace`, `/slow`. Runs as its own single event loop
    /// beside the data plane; default: no admin endpoint.
    pub fn admin_addr(mut self, addr: SocketAddr) -> Self {
        self.admin = Some(addr);
        self
    }

    /// Serve durably from `path`: the spawned server opens its engine
    /// with [`DurabilityOptions::new`] defaults rooted there (WAL +
    /// snapshots for KV, per-partition log segments for the broker) and
    /// recovers whatever state the directory already holds. Shorthand
    /// for [`ServerBuilder::durability`]; default: RAM-only.
    pub fn data_dir(self, path: impl AsRef<Path>) -> Self {
        self.durability(DurabilityOptions::new(path.as_ref()))
    }

    /// Serve durably with explicit tuning (fsync policy, segment size,
    /// snapshot cadence, broker retention). Ignored by
    /// `with_state(...).spawn()` — pre-built state decides its own
    /// durability via `KvState::open_durable` / `BrokerState::open_durable`.
    pub fn durability(mut self, opts: DurabilityOptions) -> Self {
        self.durability = Some(opts);
        self
    }

    /// Emit value payloads as shared segments over the scatter-gather
    /// write path (default `true`). Disabling re-encodes every reply
    /// into one flat buffer — the pre-zero-copy behaviour, kept as a
    /// measurable baseline for the `zerocopy` bench; copied payload
    /// bytes are then charged to the `data.bytes_copied` counter.
    pub fn zero_copy(mut self, on: bool) -> Self {
        self.zero_copy = on;
        self
    }

    /// Attach pre-built server state, selecting which server `spawn()`
    /// produces (e.g. `KvState` → KV server, `BrokerState` → broker).
    pub fn with_state<T>(self, state: T) -> ServerBuilder<T> {
        ServerBuilder {
            ingress: self.ingress,
            bind: self.bind,
            max_connections: self.max_connections,
            event_loops: self.event_loops,
            admin: self.admin,
            durability: self.durability,
            zero_copy: self.zero_copy,
            state,
        }
    }
}
