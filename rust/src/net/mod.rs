//! Server ingress infrastructure: readiness polling, the event-loop
//! reactor, and the unified [`ServerBuilder`].
//!
//! The crate's servers offer two ingress modes, selected per server via
//! [`ServerBuilder::ingress`]:
//!
//! - [`Ingress::Threaded`] — one blocking OS thread per connection.
//!   Portable and simple; threads are the scalability ceiling.
//! - [`Ingress::EventLoop`] — an [`EventLoopPool`] of a few epoll-driven
//!   reactor threads (Linux only) multiplexing every connection:
//!   nonblocking sockets, incremental frame reassembly, coalesced
//!   writes, and watch/long-poll pushes injected into the loop through
//!   [`ConnHandle`]s. Thread count stays bounded at 10k+ connections.
//!
//! Protocol logic is shared between the modes: each server implements
//! [`Service`] once and both ingresses call into the same request
//! handlers.
//!
//! The reactor also carries the crate's **HTTP admin plane**
//! ([`http::AdminService`], enabled per server via
//! [`ServerBuilder::admin_addr`]): `/metrics`, `/healthz`, `/readyz`,
//! `/conns`, `/trace` and `/slow` served by the same epoll machinery
//! under [`Framing::Http`].

pub(crate) mod builder;
pub(crate) mod event_loop;
pub mod http;
pub(crate) mod poller;
#[cfg(target_os = "linux")]
pub(crate) mod sys;

pub use builder::{Ingress, NoState, ServerBuilder};
pub use event_loop::{
    ConnHandle, EventLoopPool, FrameOutcome, FrameSeg, Framing, Service,
    WireFrame,
};
pub use http::{http_get, AdminService};
pub use poller::{PollEvent, Poller, Waker};

/// Best-effort raise of the process's open-file soft limit toward
/// `target` (never above the hard limit). Returns the resulting soft
/// limit. No-op returning `Ok(0)` on non-Linux targets. Benches that
/// ramp thousands of sockets call this first.
pub fn raise_nofile_limit(target: u64) -> std::io::Result<u64> {
    #[cfg(target_os = "linux")]
    {
        sys::raise_nofile_limit(target)
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = target;
        Ok(0)
    }
}
