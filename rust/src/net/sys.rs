//! Raw Linux syscall surface for the reactor: epoll, eventfd, writev,
//! and the fd rlimit — declared `extern "C"` against the C runtime std
//! already links, so the crate stays zero-dependency (no `libc` crate).
//! Only compiled on Linux; the poller's portable stub covers everything
//! else.

use std::io;
use std::os::fd::{FromRawFd, OwnedFd};
use std::os::raw::c_int;

/// `struct epoll_event`. The kernel ABI packs this on x86-64 (a 12-byte
/// struct); other architectures use natural alignment.
#[derive(Clone, Copy)]
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

pub const EPOLL_CTL_ADD: c_int = 1;
pub const EPOLL_CTL_DEL: c_int = 2;
pub const EPOLL_CTL_MOD: c_int = 3;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

const RLIMIT_NOFILE: c_int = 7;

#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

/// `struct iovec` for [`writev`]: one gather segment.
#[repr(C)]
pub struct IoVec {
    pub base: *const u8,
    pub len: usize,
}

/// Max segments per [`writev`] call (kernel `UIO_MAXIOV` is 1024; a
/// smaller batch keeps each syscall's copy-to-kernel bounded while still
/// amortizing it across many frames).
pub const IOV_MAX_BATCH: usize = 64;

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(
        epfd: c_int,
        op: c_int,
        fd: c_int,
        event: *mut EpollEvent,
    ) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    fn eventfd(initval: u32, flags: c_int) -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    fn writev(fd: c_int, iov: *const IoVec, iovcnt: c_int) -> isize;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// `epoll_create1(EPOLL_CLOEXEC)` as an owned fd (closed on drop).
pub fn epoll_create() -> io::Result<OwnedFd> {
    let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
    Ok(unsafe { OwnedFd::from_raw_fd(fd) })
}

/// One `epoll_ctl` call; `event` is ignored by the kernel for `DEL`.
pub fn epoll_control(
    epfd: c_int,
    op: c_int,
    fd: c_int,
    event: Option<EpollEvent>,
) -> io::Result<()> {
    let mut ev = event.unwrap_or(EpollEvent { events: 0, data: 0 });
    cvt(unsafe { epoll_ctl(epfd, op, fd, &mut ev) })?;
    Ok(())
}

/// Blocking `epoll_wait`; returns how many entries of `events` are filled.
/// A negative `timeout_ms` blocks until an event arrives.
pub fn epoll_wait_events(
    epfd: c_int,
    events: &mut [EpollEvent],
    timeout_ms: c_int,
) -> io::Result<usize> {
    let n = cvt(unsafe {
        epoll_wait(epfd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
    })?;
    Ok(n as usize)
}

/// Scatter-gather write: one syscall pushes every segment in `iov` (up
/// to a short count) without first concatenating them into a staging
/// buffer — the syscall half of the zero-copy data plane. Returns the
/// bytes written; callers handle short writes exactly as for `write`.
///
/// Safety: each `IoVec` must point at `len` readable bytes for the
/// duration of the call; the safe builder in the event loop derives them
/// from live slices.
pub fn writev_segments(fd: c_int, iov: &[IoVec]) -> io::Result<usize> {
    let cnt = iov.len().min(IOV_MAX_BATCH) as c_int;
    let n = unsafe { writev(fd, iov.as_ptr(), cnt) };
    if n < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(n as usize)
    }
}

/// Nonblocking eventfd as an owned fd — the loop's cross-thread waker.
pub fn eventfd_create() -> io::Result<OwnedFd> {
    let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
    Ok(unsafe { OwnedFd::from_raw_fd(fd) })
}

/// Best-effort raise of the open-file soft limit toward `target` (capped
/// at the hard limit). Returns the resulting soft limit. The c1m bench
/// calls this before ramping tens of thousands of sockets.
pub fn raise_nofile_limit(target: u64) -> io::Result<u64> {
    let mut lim = RLimit { cur: 0, max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    if lim.cur >= target {
        return Ok(lim.cur);
    }
    let wanted = target.min(lim.max);
    let new = RLimit { cur: wanted, max: lim.max };
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &new) })?;
    Ok(wanted)
}
