//! Deterministic PRNG substrate (no `rand` crate in the offline registry).
//!
//! A [splitmix64](https://prng.di.unimi.it/splitmix64.c)-seeded
//! xoshiro256** generator: fast, high quality, and reproducible across
//! runs, which the benchmark harness and property-testing framework both
//! rely on.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Seed from the system clock (for non-reproducible contexts only).
    pub fn from_entropy() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0xDEAD_BEEF);
        Self::new(nanos ^ (std::process::id() as u64) << 32)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; `n` must be > 0. Uses Lemire's method.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "usize_in empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// A vec of `n` pseudo-random bytes (synthetic payload data).
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill_bytes(&mut v);
        v
    }

    /// Fork a child generator (for per-worker reproducibility).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn bytes_len_and_determinism() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        assert_eq!(a.bytes(37), b.bytes(37));
        assert_eq!(a.bytes(0).len(), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
