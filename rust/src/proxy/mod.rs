//! The transparent lazy object proxy (Sec III of the paper).
//!
//! A [`Proxy<T>`] is a wide-area reference to a target object living in a
//! mediated channel. It is *self-contained*: the embedded [`Factory`]
//! carries everything needed to resolve the target (connector descriptor +
//! key + wait semantics), so a proxy can be serialized, shipped to any
//! process, and resolved there with no ambient state. It is *lazy*: bytes
//! move only on first dereference, and the decoded target is cached in the
//! proxy thereafter.
//!
//! Rust cannot fake `isinstance(p, type(t))` the way Python's dynamic
//! dispatch can; the idiomatic analogue is `Deref<Target = T>`: any `&T`
//! consumer accepts `&Proxy<T>` via auto-deref, which is the property the
//! paper's patterns actually rely on (consumer code unchanged between
//! values and proxies).
//!
//! Resolution consults a process-local LRU [`cache`] (ProxyStore's
//! per-process target cache): re-resolving the same key serves the blob
//! from memory. Store keys are never reused, so cached blobs cannot be
//! stale reads — writers that rewrite a key in place (`OwnedProxy::update`,
//! `RefMutProxy::commit`) and evictors invalidate the entry explicitly.

use std::marker::PhantomData;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::codec::{Decode, Encode, Reader};
use crate::error::{Error, Result};
use crate::store::{ConnectorDesc, Connector};

pub mod cache;

/// Resolution metadata embedded in every proxy.
#[derive(Debug, Clone, PartialEq)]
pub struct Factory {
    /// How to reach the mediated channel.
    pub desc: ConnectorDesc,
    /// Key of the target object.
    pub key: String,
    /// If true, resolution blocks until the target exists (ProxyFutures).
    pub wait: bool,
    /// Wait bound in ms (0 = forever) when `wait` is set.
    pub timeout_ms: u64,
    /// Creating store's name (diagnostics + ownership bookkeeping).
    pub store_name: String,
}

impl Encode for Factory {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.desc.encode(buf);
        self.key.encode(buf);
        self.wait.encode(buf);
        self.timeout_ms.encode(buf);
        self.store_name.encode(buf);
    }
}

impl Decode for Factory {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Factory {
            desc: Decode::decode(r)?,
            key: Decode::decode(r)?,
            wait: Decode::decode(r)?,
            timeout_ms: Decode::decode(r)?,
            store_name: Decode::decode(r)?,
        })
    }
}

/// Process-wide connector cache so resolving many proxies against the same
/// channel reuses one connection (keyed by the encoded descriptor).
fn connector_cache() -> &'static std::sync::Mutex<
    std::collections::HashMap<Vec<u8>, Arc<dyn Connector>>,
> {
    static CACHE: OnceLock<
        std::sync::Mutex<std::collections::HashMap<Vec<u8>, Arc<dyn Connector>>>,
    > = OnceLock::new();
    CACHE.get_or_init(Default::default)
}

impl Factory {
    /// Connector for this factory, via the process-wide cache.
    pub fn connector(&self) -> Result<Arc<dyn Connector>> {
        let key = self.desc.to_bytes();
        if let Some(c) = connector_cache().lock().unwrap().get(&key) {
            return Ok(c.clone());
        }
        let c = self.desc.connect()?;
        connector_cache().lock().unwrap().insert(key, c.clone());
        Ok(c)
    }

    /// Fetch the raw target bytes, honouring wait semantics. The blob
    /// shares the connector's allocation where possible (memory channel)
    /// and is served from / published to the process-local LRU cache.
    /// Wait-mode resolution (ProxyFutures) arms an out-of-band watch and
    /// parks on the handle: the producer's write pushes the value to the
    /// waiter in one wire push — no polling, no parked server connection.
    pub fn fetch_bytes(&self) -> Result<crate::store::Blob> {
        let desc_bytes = self.desc.to_bytes();
        if let Some(blob) = cache::global().get(&desc_bytes, &self.key) {
            return Ok(blob);
        }
        let conn = self.connector()?;
        let timeout = if self.timeout_ms == 0 {
            None
        } else {
            Some(Duration::from_millis(self.timeout_ms))
        };
        let got = if self.wait {
            let handle = conn.watch(&self.key);
            match timeout {
                None => Some(handle.wait()?),
                Some(t) => handle.wait_timeout(t)?,
            }
        } else {
            conn.get(&self.key)?
        };
        match got {
            Some(blob) => {
                cache::global().put(&desc_bytes, &self.key, blob.clone());
                Ok(blob)
            }
            None if self.wait => Err(Error::Timeout(
                timeout.unwrap_or_default(),
                format!("future target {} never set", self.key),
            )),
            None => Err(Error::NotFound(self.key.clone())),
        }
    }

    /// Drop any process-local cached copy of this factory's target.
    pub fn invalidate_cache(&self) {
        cache::global().invalidate(&self.desc.to_bytes(), &self.key);
    }
}

/// Batch-prefetch the targets of unresolved proxies into the process-local
/// blob cache, grouping keys by connector so each channel sees one batched
/// `get_many` (one wire round trip on the KV connector; a parallel fan-out
/// on the shard fabric). Every group's batch is *submitted* before any
/// result is collected ([`crate::ops::submit`]), so proxies spanning
/// several channels resolve with overlapped round trips instead of one
/// channel at a time. Streaming consumers call this on a window of
/// pending proxies to amortize round trips; subsequent
/// [`Proxy::resolve`] calls are then served from memory.
///
/// Proxies that are already resolved, already cached, or in wait mode are
/// skipped: a wait-mode target may not exist yet, and prefetch must stay
/// bounded — arming watches here would park the collection on the slowest
/// producer (arm [`ProxyFuture::result_async`](crate::futures::ProxyFuture::result_async)
/// or [`crate::futures::when_all`] for that). Missing targets are left
/// for `resolve` to report. Returns the number of targets actually
/// fetched.
pub fn prefetch<T>(proxies: &[Proxy<T>]) -> Result<usize> {
    let mut groups: std::collections::HashMap<Vec<u8>, Vec<&Factory>> =
        std::collections::HashMap::new();
    for p in proxies {
        if p.is_resolved() || p.factory.wait {
            continue;
        }
        let desc_bytes = p.factory.desc.to_bytes();
        if cache::global().get(&desc_bytes, &p.factory.key).is_some() {
            continue;
        }
        groups.entry(desc_bytes).or_default().push(&p.factory);
    }
    // Submit every group's batched get, then collect: channels overlap.
    let mut in_flight = Vec::with_capacity(groups.len());
    for (desc_bytes, factories) in groups {
        let conn = factories[0].connector()?;
        let keys: Vec<String> =
            factories.iter().map(|f| f.key.clone()).collect();
        let handle = crate::ops::submit(&conn, crate::ops::Op::GetMany { keys });
        in_flight.push((desc_bytes, factories, handle));
    }
    let mut fetched = 0;
    for (desc_bytes, factories, handle) in in_flight {
        let blobs = handle.wait()?.into_values()?;
        for (factory, blob) in factories.iter().zip(blobs) {
            if let Some(blob) = blob {
                cache::global().put(&desc_bytes, &factory.key, blob);
                fetched += 1;
            }
        }
    }
    Ok(fetched)
}

/// Lazy transparent proxy for a `T` stored in a mediated channel.
pub struct Proxy<T> {
    factory: Factory,
    cell: OnceLock<T>,
    _marker: PhantomData<fn() -> T>,
}

impl<T> Proxy<T> {
    /// Build from a factory (used by `Store::proxy` and friends).
    pub fn from_factory(factory: Factory) -> Proxy<T> {
        Proxy { factory, cell: OnceLock::new(), _marker: PhantomData }
    }

    /// A pre-resolved proxy (factory metadata + local target already in
    /// hand). Used when the creating process keeps using the object.
    pub fn preresolved(factory: Factory, value: T) -> Proxy<T> {
        let cell = OnceLock::new();
        let _ = cell.set(value);
        Proxy { factory, cell, _marker: PhantomData }
    }

    pub fn factory(&self) -> &Factory {
        &self.factory
    }

    pub fn key(&self) -> &str {
        &self.factory.key
    }

    /// Has the target already been fetched into this proxy?
    pub fn is_resolved(&self) -> bool {
        self.cell.get().is_some()
    }
}

impl<T: Decode> Proxy<T> {
    /// Resolve (fetch + decode + cache) and return the target.
    pub fn resolve(&self) -> Result<&T> {
        if let Some(v) = self.cell.get() {
            return Ok(v);
        }
        let blob = self.factory.fetch_bytes()?;
        // Single-owner blobs (TCP/file reads) decode by moving the buffer;
        // shared blobs (memory channel) decode by copy — the consumer's
        // pass-by-value copy the proxy model promises.
        let value = match Arc::try_unwrap(blob) {
            Ok(owned) => T::from_owned(owned)?,
            Err(shared) => T::from_bytes(&shared)?,
        };
        // Another thread may have won the race; either value is identical.
        let _ = self.cell.set(value);
        Ok(self.cell.get().expect("cell just set"))
    }

    /// Resolve and take ownership of the target (consumes the proxy).
    pub fn into_inner(self) -> Result<T> {
        if self.cell.get().is_none() {
            self.resolve()?;
        }
        Ok(self.cell.into_inner().expect("resolved above"))
    }
}

impl<T: Decode> std::ops::Deref for Proxy<T> {
    type Target = T;

    /// Transparent access; panics on resolution failure (use
    /// [`Proxy::resolve`] for a fallible path), mirroring how a Python
    /// proxy raises on a failed just-in-time resolution.
    fn deref(&self) -> &T {
        self.resolve().expect("proxy resolution failed")
    }
}

impl<T> Clone for Proxy<T> {
    /// Cloning copies the reference (factory), not the cached target —
    /// pass-by-reference semantics.
    fn clone(&self) -> Self {
        Proxy::from_factory(self.factory.clone())
    }
}

impl<T> std::fmt::Debug for Proxy<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Proxy")
            .field("key", &self.factory.key)
            .field("wait", &self.factory.wait)
            .field("resolved", &self.is_resolved())
            .finish()
    }
}

impl<T> Encode for Proxy<T> {
    /// Only the factory crosses the wire — the cheap-reference property.
    fn encode(&self, buf: &mut Vec<u8>) {
        self.factory.encode(buf);
    }
}

impl<T> Decode for Proxy<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Proxy::from_factory(Factory::decode(r)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::Store;

    #[test]
    fn proxy_resolves_lazily() {
        let store = Store::memory("t-lazy");
        let p: Proxy<String> = store.proxy(&"hello".to_string()).unwrap();
        assert!(!p.is_resolved());
        assert_eq!(p.resolve().unwrap(), "hello");
        assert!(p.is_resolved());
        // Deref transparency.
        assert_eq!(p.len(), 5);
    }

    #[test]
    fn proxy_serializes_as_reference() {
        let store = Store::memory("t-serde");
        let big = vec![42u8; 1 << 20];
        let p: Proxy<crate::codec::Bytes> =
            store.proxy(&crate::codec::Bytes(big.clone())).unwrap();
        let wire = p.to_bytes();
        assert!(wire.len() < 256, "proxy wire size {} too big", wire.len());
        let p2: Proxy<crate::codec::Bytes> =
            Proxy::from_bytes(&wire).unwrap();
        assert_eq!(p2.resolve().unwrap().0, big);
    }

    #[test]
    fn clone_is_reference_copy() {
        let store = Store::memory("t-clone");
        let p: Proxy<u64> = store.proxy(&7u64).unwrap();
        p.resolve().unwrap();
        let c = p.clone();
        assert!(!c.is_resolved());
        assert_eq!(*c.resolve().unwrap(), 7);
    }

    #[test]
    fn missing_key_is_not_found() {
        let store = Store::memory("t-missing");
        let p: Proxy<u64> = store.proxy(&1u64).unwrap();
        store.evict(p.key()).unwrap();
        let fresh: Proxy<u64> = Proxy::from_bytes(&p.to_bytes()).unwrap();
        match fresh.resolve() {
            Err(Error::NotFound(_)) => {}
            other => panic!("expected NotFound, got {other:?}"),
        }
    }

    #[test]
    fn prefetch_populates_cache_and_skips_misses() {
        let store = Store::memory("t-prefetch");
        let objs: Vec<crate::codec::Bytes> = (0..8)
            .map(|i| crate::codec::Bytes(vec![i as u8; 2048]))
            .collect();
        let proxies = store.proxy_many(&objs).unwrap();
        // Ship them "elsewhere": fresh unresolved copies.
        let shipped: Vec<Proxy<crate::codec::Bytes>> = proxies
            .iter()
            .map(|p| Proxy::from_bytes(&p.to_bytes()).unwrap())
            .collect();
        let fetched = prefetch(&shipped).unwrap();
        assert_eq!(fetched, 8);
        // Already-cached: a second prefetch fetches nothing.
        assert_eq!(prefetch(&shipped).unwrap(), 0);
        for (i, p) in shipped.iter().enumerate() {
            assert_eq!(p.resolve().unwrap().0, vec![i as u8; 2048]);
        }
        // Evicted targets are skipped, not errors; resolve reports them.
        let victim: Proxy<crate::codec::Bytes> = store
            .proxy(&crate::codec::Bytes(vec![9; 64]))
            .unwrap();
        let cold: Proxy<crate::codec::Bytes> =
            Proxy::from_bytes(&victim.to_bytes()).unwrap();
        store.evict(victim.key()).unwrap();
        assert_eq!(prefetch(&[cold.clone()]).unwrap(), 0);
        assert!(matches!(cold.resolve(), Err(Error::NotFound(_))));
    }

    #[test]
    fn into_inner_takes_value() {
        let store = Store::memory("t-into");
        let p: Proxy<String> = store.proxy(&"v".to_string()).unwrap();
        let s = p.into_inner().unwrap();
        assert_eq!(s, "v");
    }
}
