//! Process-local LRU blob cache for proxy resolution.
//!
//! ProxyStore caches deserialized targets per process so that resolving
//! many proxies of the same object (or re-resolving after a clone) does
//! not re-fetch bulk bytes. Keys are never reused by `Store::new_key`, so
//! a cached blob can never be stale — at worst it outlives its store copy,
//! which is exactly the pass-by-value copy semantics proxies promise.
//!
//! The cache is byte-budgeted LRU, keyed by `(connector-desc, key)`.
//! Capacity comes from `PROXYSTORE_CACHE_BYTES` (default 64 MiB; 0
//! disables). Wait-mode (future) factories bypass the cache before the
//! value exists and populate it after.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::store::Blob;

/// Byte-budgeted LRU of resolution blobs.
pub struct BlobCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

struct CacheInner {
    map: HashMap<(Vec<u8>, String), (Blob, u64)>,
    bytes: usize,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl BlobCache {
    pub fn new(capacity: usize) -> BlobCache {
        BlobCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                bytes: 0,
                tick: 0,
                hits: 0,
                misses: 0,
            }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look up a blob, refreshing its recency.
    pub fn get(&self, desc: &[u8], key: &str) -> Option<Blob> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&(desc.to_vec(), key.to_string())) {
            Some((blob, stamp)) => {
                *stamp = tick;
                let out = blob.clone();
                inner.hits += 1;
                Some(out)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a blob, evicting least-recently-used entries over budget.
    /// Blobs larger than the whole budget are not cached.
    pub fn put(&self, desc: &[u8], key: &str, blob: Blob) {
        if self.capacity == 0 || blob.len() > self.capacity {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry_key = (desc.to_vec(), key.to_string());
        if let Some((old, _)) = inner.map.insert(entry_key, (blob.clone(), tick))
        {
            inner.bytes -= old.len();
        }
        inner.bytes += blob.len();
        while inner.bytes > self.capacity {
            // Evict the least recently used entry.
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some((b, _)) = inner.map.remove(&k) {
                        inner.bytes -= b.len();
                    }
                }
                None => break,
            }
        }
    }

    /// Drop a key (called on explicit store evictions routed through the
    /// same process, keeping the common single-process tests intuitive).
    pub fn invalidate(&self, desc: &[u8], key: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some((b, _)) = inner.map.remove(&(desc.to_vec(), key.to_string()))
        {
            inner.bytes -= b.len();
        }
    }

    /// (hits, misses, resident bytes).
    pub fn stats(&self) -> (u64, u64, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses, inner.bytes)
    }

    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.bytes = 0;
    }
}

/// The process-wide resolution cache (capacity from
/// `PROXYSTORE_CACHE_BYTES`, default 64 MiB).
pub fn global() -> &'static BlobCache {
    static CACHE: std::sync::OnceLock<BlobCache> = std::sync::OnceLock::new();
    CACHE.get_or_init(|| {
        let cap = std::env::var("PROXYSTORE_CACHE_BYTES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64 * 1024 * 1024);
        BlobCache::new(cap)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn blob(n: usize, fill: u8) -> Blob {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn hit_after_put() {
        let c = BlobCache::new(1000);
        assert!(c.get(b"d", "k").is_none());
        c.put(b"d", "k", blob(100, 1));
        let got = c.get(b"d", "k").unwrap();
        assert_eq!(got.len(), 100);
        let (hits, misses, bytes) = c.stats();
        assert_eq!((hits, misses, bytes), (1, 1, 100));
    }

    #[test]
    fn lru_eviction_order() {
        let c = BlobCache::new(250);
        c.put(b"d", "a", blob(100, 1));
        c.put(b"d", "b", blob(100, 2));
        c.get(b"d", "a"); // refresh a
        c.put(b"d", "c", blob(100, 3)); // evicts b (LRU)
        assert!(c.get(b"d", "a").is_some());
        assert!(c.get(b"d", "b").is_none());
        assert!(c.get(b"d", "c").is_some());
        let (_, _, bytes) = c.stats();
        assert!(bytes <= 250);
    }

    #[test]
    fn oversized_blob_not_cached() {
        let c = BlobCache::new(50);
        c.put(b"d", "big", blob(100, 1));
        assert!(c.get(b"d", "big").is_none());
    }

    #[test]
    fn zero_capacity_disables() {
        let c = BlobCache::new(0);
        c.put(b"d", "k", blob(10, 1));
        assert!(c.get(b"d", "k").is_none());
    }

    #[test]
    fn overwrite_adjusts_bytes() {
        let c = BlobCache::new(1000);
        c.put(b"d", "k", blob(100, 1));
        c.put(b"d", "k", blob(50, 2));
        let (_, _, bytes) = c.stats();
        assert_eq!(bytes, 50);
        assert_eq!(c.get(b"d", "k").unwrap()[0], 2);
    }

    #[test]
    fn invalidate_and_clear() {
        let c = BlobCache::new(1000);
        c.put(b"d", "k", blob(10, 1));
        c.invalidate(b"d", "k");
        assert!(c.get(b"d", "k").is_none());
        c.put(b"d", "x", blob(10, 1));
        c.clear();
        let (_, _, bytes) = c.stats();
        assert_eq!(bytes, 0);
    }

    #[test]
    fn distinct_descs_do_not_collide() {
        let c = BlobCache::new(1000);
        c.put(b"d1", "k", blob(10, 1));
        c.put(b"d2", "k", blob(10, 2));
        assert_eq!(c.get(b"d1", "k").unwrap()[0], 1);
        assert_eq!(c.get(b"d2", "k").unwrap()[0], 2);
    }
}
