//! ProxyFutures: compute-framework-agnostic distributed futures (Sec IV-A).
//!
//! A [`ProxyFuture<T>`] is created from a `Store` *before its value
//! exists*. It can mint any number of [`Proxy<T>`]s whose resolution
//! blocks until some process calls [`ProxyFuture::set_result`]. Both the
//! future and its proxies are plain data (codec-serializable), so they can
//! be passed to tasks on any execution engine — the property that
//! distinguishes them from Dask/Ray futures, which only resolve inside
//! their RPC framework.
//!
//! The blocking rendezvous rides the connector's `wait_get` (server-side
//! parking on redis-sim, poll-with-backoff elsewhere), so the *future
//! creator* chooses the communication method on behalf of producer and
//! consumer, exactly as the paper prescribes.

use std::marker::PhantomData;
use std::time::Duration;

use crate::codec::{Decode, Encode, Reader};
use crate::error::{Error, Result};
use crate::proxy::{Factory, Proxy};

/// A distributed future for an eventual value of type `T`.
pub struct ProxyFuture<T> {
    factory: Factory,
    _marker: PhantomData<fn() -> T>,
}

impl<T> ProxyFuture<T> {
    /// Build from a wait-enabled factory (see `Store::future`).
    pub fn new(factory: Factory) -> ProxyFuture<T> {
        debug_assert!(factory.wait, "future factories must wait");
        ProxyFuture { factory, _marker: PhantomData }
    }

    /// The key the eventual value will be stored under.
    pub fn key(&self) -> &str {
        &self.factory.key
    }

    /// Mint a proxy that blocks (forever) on resolution until the result
    /// is set. Any number of proxies can be created.
    pub fn proxy(&self) -> Proxy<T> {
        Proxy::from_factory(self.factory.clone())
    }

    /// Mint a proxy whose resolution gives up after `timeout`.
    pub fn proxy_with_timeout(&self, timeout: Duration) -> Proxy<T> {
        let mut f = self.factory.clone();
        f.timeout_ms = timeout.as_millis() as u64;
        Proxy::from_factory(f)
    }

    /// Has the result been set yet?
    pub fn done(&self) -> Result<bool> {
        self.factory.connector()?.exists(&self.factory.key)
    }
}

impl<T: Encode> ProxyFuture<T> {
    /// Publish the result. Errors if already set (single-assignment).
    pub fn set_result(&self, value: &T) -> Result<()> {
        let conn = self.factory.connector()?;
        if conn.exists(&self.factory.key)? {
            return Err(Error::Config(format!(
                "future {} already set",
                self.factory.key
            )));
        }
        conn.put(&self.factory.key, value.to_bytes())
    }
}

impl<T: Decode> ProxyFuture<T> {
    /// Block for the result (explicit-future interface).
    pub fn result(&self, timeout: Option<Duration>) -> Result<T> {
        let conn = self.factory.connector()?;
        match conn.wait_get(&self.factory.key, timeout)? {
            Some(bytes) => T::from_bytes(&bytes),
            None => Err(Error::Timeout(
                timeout.unwrap_or_default(),
                format!("future {}", self.factory.key),
            )),
        }
    }
}

impl<T> Clone for ProxyFuture<T> {
    fn clone(&self) -> Self {
        ProxyFuture::new(self.factory.clone())
    }
}

impl<T> std::fmt::Debug for ProxyFuture<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProxyFuture")
            .field("key", &self.factory.key)
            .finish()
    }
}

impl<T> Encode for ProxyFuture<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.factory.encode(buf);
    }
}

impl<T> Decode for ProxyFuture<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ProxyFuture::new(Factory::decode(r)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::KvServer;
    use crate::store::{Store, TcpKvConnector};
    use std::sync::Arc;

    #[test]
    fn set_then_resolve() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<String> = store.future();
        assert!(!fut.done().unwrap());
        let p = fut.proxy();
        fut.set_result(&"ready".to_string()).unwrap();
        assert!(fut.done().unwrap());
        assert_eq!(p.resolve().unwrap(), "ready");
    }

    #[test]
    fn consumer_blocks_until_producer_sets() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        let p = fut.proxy();
        let consumer = std::thread::spawn(move || *p.resolve().unwrap());
        std::thread::sleep(Duration::from_millis(30));
        fut.set_result(&99u64).unwrap();
        assert_eq!(consumer.join().unwrap(), 99);
    }

    #[test]
    fn proxy_created_before_value_exists_and_ships_across_threads() {
        // The M/P/C scenario from Sec IV-A: main mints future+proxy, ships
        // the future to a producer thread and the proxy to a consumer
        // thread, via plain bytes (simulating engine serialization).
        let server = KvServer::spawn().unwrap();
        let store =
            Store::new("fut", Arc::new(TcpKvConnector::connect(server.addr).unwrap()));
        let fut: ProxyFuture<String> = store.future();
        let fut_wire = fut.to_bytes();
        let proxy_wire = fut.proxy().to_bytes();

        let producer = std::thread::spawn(move || {
            let f: ProxyFuture<String> =
                ProxyFuture::from_bytes(&fut_wire).unwrap();
            std::thread::sleep(Duration::from_millis(40));
            f.set_result(&"produced".to_string()).unwrap();
        });
        let consumer = std::thread::spawn(move || {
            let p: Proxy<String> = Proxy::from_bytes(&proxy_wire).unwrap();
            p.resolve().unwrap().clone()
        });
        producer.join().unwrap();
        assert_eq!(consumer.join().unwrap(), "produced");
    }

    #[test]
    fn timeout_proxy_errors() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        let p = fut.proxy_with_timeout(Duration::from_millis(30));
        match p.resolve() {
            Err(Error::Timeout(..)) => {}
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn double_set_rejected() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        fut.set_result(&1).unwrap();
        assert!(fut.set_result(&2).is_err());
        assert_eq!(fut.result(None).unwrap(), 1);
    }

    #[test]
    fn explicit_result_interface() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        let f2 = fut.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            f2.set_result(&5).unwrap();
        });
        assert_eq!(fut.result(Some(Duration::from_secs(5))).unwrap(), 5);
        // Timeout path
        let never: ProxyFuture<u64> = store.future();
        assert!(matches!(
            never.result(Some(Duration::from_millis(20))),
            Err(Error::Timeout(..))
        ));
    }

    #[test]
    fn many_proxies_one_future() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u32> = store.future();
        let proxies: Vec<Proxy<u32>> = (0..8).map(|_| fut.proxy()).collect();
        fut.set_result(&7).unwrap();
        for p in proxies {
            assert_eq!(*p.resolve().unwrap(), 7);
        }
    }
}
