//! ProxyFutures: compute-framework-agnostic distributed futures (Sec IV-A).
//!
//! A [`ProxyFuture<T>`] is created from a `Store` *before its value
//! exists*. It can mint any number of [`Proxy<T>`]s whose resolution
//! blocks until some process calls [`ProxyFuture::set_result`]. Both the
//! future and its proxies are plain data (codec-serializable), so they can
//! be passed to tasks on any execution engine — the property that
//! distinguishes them from Dask/Ray futures, which only resolve inside
//! their RPC framework.
//!
//! The blocking rendezvous rides the connector's out-of-band **watch
//! plane** ([`Connector::watch`](crate::store::Connector::watch)): a
//! consumer arms a watch and parks on the completion handle, waking in
//! one push when the producer's write fires the registered waiter —
//! server-push on TCP channels, a registry callback in-process, a poll
//! bridge only where the channel offers nothing better. The *future
//! creator* chooses the communication method on behalf of producer and
//! consumer, exactly as the paper prescribes. [`ProxyFuture::result_async`]
//! exposes the armed handle directly, and the [`when_all`]/[`when_any`]
//! combinators fan joins in over watch handles — N pending keys park
//! once each instead of polling.
//!
//! Single assignment is atomic: [`ProxyFuture::set_result`] rides
//! [`Connector::put_nx`](crate::store::Connector::put_nx), so two
//! producers racing to resolve one future get exactly one winner (no
//! exists-then-put window).

use std::marker::PhantomData;
use std::time::{Duration, Instant};

use crate::codec::{Decode, Encode, Reader};
use crate::error::{Error, Result};
use crate::ops::Pending;
use crate::proxy::{Factory, Proxy};
use crate::store::Blob;

/// A distributed future for an eventual value of type `T`.
pub struct ProxyFuture<T> {
    factory: Factory,
    _marker: PhantomData<fn() -> T>,
}

impl<T> ProxyFuture<T> {
    /// Build from a wait-enabled factory (see `Store::future`).
    pub fn new(factory: Factory) -> ProxyFuture<T> {
        debug_assert!(factory.wait, "future factories must wait");
        ProxyFuture { factory, _marker: PhantomData }
    }

    /// The key the eventual value will be stored under.
    pub fn key(&self) -> &str {
        &self.factory.key
    }

    /// Mint a proxy that blocks (forever) on resolution until the result
    /// is set. Any number of proxies can be created.
    pub fn proxy(&self) -> Proxy<T> {
        Proxy::from_factory(self.factory.clone())
    }

    /// Mint a proxy whose resolution gives up after `timeout`.
    pub fn proxy_with_timeout(&self, timeout: Duration) -> Proxy<T> {
        let mut f = self.factory.clone();
        f.timeout_ms = timeout.as_millis() as u64;
        Proxy::from_factory(f)
    }

    /// Has the result been set yet?
    pub fn done(&self) -> Result<bool> {
        self.factory.connector()?.exists(&self.factory.key)
    }
}

impl<T: Encode> ProxyFuture<T> {
    /// Publish the result. Errors if already set: single-assignment is
    /// decided *atomically* by the channel's conditional write
    /// ([`Connector::put_nx`](crate::store::Connector::put_nx)), so two
    /// producers racing on one future get exactly one winner — there is
    /// no exists-then-put window for both to slip through.
    pub fn set_result(&self, value: &T) -> Result<()> {
        let conn = self.factory.connector()?;
        if conn.put_nx(&self.factory.key, value.to_bytes())? {
            Ok(())
        } else {
            Err(Error::Config(format!(
                "future {} already set",
                self.factory.key
            )))
        }
    }
}

impl<T: Decode> ProxyFuture<T> {
    /// Block for the result (explicit-future interface): arm a watch and
    /// park on the handle — one push wakes the wait, no polling and no
    /// parked server connection.
    pub fn result(&self, timeout: Option<Duration>) -> Result<T> {
        let handle = self.factory.connector()?.watch(&self.factory.key);
        let blob = match timeout {
            None => handle.wait()?,
            Some(t) => handle.wait_timeout(t)?.ok_or_else(|| {
                Error::Timeout(t, format!("future {}", self.factory.key))
            })?,
        };
        T::from_bytes(&blob)
    }

    /// Arm the watch *now* and hand back a typed completion handle, so
    /// the wait overlaps with compute: the consumer keeps working and
    /// takes the value where it's needed ([`PendingResult::wait`]). The
    /// nonblocking twin of [`ProxyFuture::result`].
    pub fn result_async(&self) -> Result<PendingResult<T>> {
        Ok(PendingResult {
            handle: self.factory.connector()?.watch(&self.factory.key),
            key: self.factory.key.clone(),
            _marker: PhantomData,
        })
    }
}

/// Typed completion handle for an armed future watch
/// ([`ProxyFuture::result_async`]): decode happens at take time. Mirrors
/// [`Pending`] semantics — the value moves out exactly once; a second
/// take reports an error rather than hanging.
pub struct PendingResult<T> {
    handle: Pending<Blob>,
    key: String,
    _marker: PhantomData<fn() -> T>,
}

impl<T: Decode> PendingResult<T> {
    /// The key the result will appear under.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Whether the result has been published.
    pub fn is_complete(&self) -> bool {
        self.handle.is_complete()
    }

    /// Block until the result is published; decode and take it.
    pub fn wait(&self) -> Result<T> {
        T::from_bytes(&self.handle.wait()?)
    }

    /// Bounded wait: `Ok(None)` if still unpublished when the timeout
    /// elapses (the handle stays usable; wait again later).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<Option<T>> {
        match self.handle.wait_timeout(timeout)? {
            Some(blob) => Ok(Some(T::from_bytes(&blob)?)),
            None => Ok(None),
        }
    }
}

impl<T> std::fmt::Debug for PendingResult<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PendingResult")
            .field("key", &self.key)
            .field("complete", &self.handle.is_complete())
            .finish()
    }
}

/// Wait for *every* future, parking once per key instead of polling N
/// keys (the fan-in join of the paper's dynamic task graphs, Sec IV-A).
/// All watches are armed before any wait begins, so the slowest producer
/// bounds wall time; the shared `timeout` spans the whole join. Results
/// align positionally with `futs`.
pub fn when_all<T: Decode>(
    futs: &[ProxyFuture<T>],
    timeout: Option<Duration>,
) -> Result<Vec<T>> {
    let handles: Vec<Pending<Blob>> = futs
        .iter()
        .map(|f| Ok(f.factory.connector()?.watch(&f.factory.key)))
        .collect::<Result<_>>()?;
    let deadline = timeout.map(|t| Instant::now() + t);
    let mut out = Vec::with_capacity(handles.len());
    for (handle, fut) in handles.iter().zip(futs) {
        let blob = match deadline {
            None => handle.wait()?,
            Some(d) => {
                let left = d.saturating_duration_since(Instant::now());
                handle.wait_timeout(left)?.ok_or_else(|| {
                    Error::Timeout(
                        timeout.unwrap_or_default(),
                        format!("when_all: future {}", fut.factory.key),
                    )
                })?
            }
        };
        out.push(T::from_bytes(&blob)?);
    }
    Ok(out)
}

/// Wait for the *first* future to resolve; returns its index and value.
/// Thread-free fan-in on the watch plane's racing primitive
/// ([`crate::ops::Race`]): every watch handle delivers through an
/// index-tagged arm into one shared completion, so N armed keys cost one
/// parked waiter — and once a winner lands, the losing arms read as
/// abandoned, releasing any poll-bridge producers behind them. Fails
/// only if every armed watch fails (e.g. every backend died).
pub fn when_any<T: Decode>(
    futs: &[ProxyFuture<T>],
    timeout: Option<Duration>,
) -> Result<(usize, T)> {
    if futs.is_empty() {
        return Err(Error::Config("when_any on an empty future set".into()));
    }
    let (group, out) = crate::ops::race::<(usize, Blob)>();
    for (i, fut) in futs.iter().enumerate() {
        let handle = match fut.factory.connector() {
            Ok(conn) => conn.watch(&fut.factory.key),
            Err(e) => Pending::ready(Err(e)),
        };
        group.add_map(handle, move |blob| (i, blob));
    }
    let (i, blob) = match timeout {
        None => out.wait()?,
        Some(t) => out
            .wait_timeout(t)?
            .ok_or_else(|| Error::Timeout(t, "when_any".into()))?,
    };
    Ok((i, T::from_bytes(&blob)?))
}

impl<T> Clone for ProxyFuture<T> {
    fn clone(&self) -> Self {
        ProxyFuture::new(self.factory.clone())
    }
}

impl<T> std::fmt::Debug for ProxyFuture<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProxyFuture")
            .field("key", &self.factory.key)
            .finish()
    }
}

impl<T> Encode for ProxyFuture<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.factory.encode(buf);
    }
}

impl<T> Decode for ProxyFuture<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ProxyFuture::new(Factory::decode(r)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::ServerBuilder;
    use crate::store::{Store, TcpKvConnector};
    use std::sync::Arc;

    #[test]
    fn set_then_resolve() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<String> = store.future();
        assert!(!fut.done().unwrap());
        let p = fut.proxy();
        fut.set_result(&"ready".to_string()).unwrap();
        assert!(fut.done().unwrap());
        assert_eq!(p.resolve().unwrap(), "ready");
    }

    #[test]
    fn consumer_blocks_until_producer_sets() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        let p = fut.proxy();
        let consumer = std::thread::spawn(move || *p.resolve().unwrap());
        std::thread::sleep(Duration::from_millis(30));
        fut.set_result(&99u64).unwrap();
        assert_eq!(consumer.join().unwrap(), 99);
    }

    #[test]
    fn proxy_created_before_value_exists_and_ships_across_threads() {
        // The M/P/C scenario from Sec IV-A: main mints future+proxy, ships
        // the future to a producer thread and the proxy to a consumer
        // thread, via plain bytes (simulating engine serialization).
        let server = ServerBuilder::new().spawn_kv().unwrap();
        let store =
            Store::new("fut", Arc::new(TcpKvConnector::connect(server.addr).unwrap()));
        let fut: ProxyFuture<String> = store.future();
        let fut_wire = fut.to_bytes();
        let proxy_wire = fut.proxy().to_bytes();

        let producer = std::thread::spawn(move || {
            let f: ProxyFuture<String> =
                ProxyFuture::from_bytes(&fut_wire).unwrap();
            std::thread::sleep(Duration::from_millis(40));
            f.set_result(&"produced".to_string()).unwrap();
        });
        let consumer = std::thread::spawn(move || {
            let p: Proxy<String> = Proxy::from_bytes(&proxy_wire).unwrap();
            p.resolve().unwrap().clone()
        });
        producer.join().unwrap();
        assert_eq!(consumer.join().unwrap(), "produced");
    }

    #[test]
    fn timeout_proxy_errors() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        let p = fut.proxy_with_timeout(Duration::from_millis(30));
        match p.resolve() {
            Err(Error::Timeout(..)) => {}
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn double_set_rejected() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        fut.set_result(&1).unwrap();
        assert!(fut.set_result(&2).is_err());
        assert_eq!(fut.result(None).unwrap(), 1);
    }

    #[test]
    fn explicit_result_interface() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u64> = store.future();
        let f2 = fut.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            f2.set_result(&5).unwrap();
        });
        assert_eq!(fut.result(Some(Duration::from_secs(5))).unwrap(), 5);
        // Timeout path
        let never: ProxyFuture<u64> = store.future();
        assert!(matches!(
            never.result(Some(Duration::from_millis(20))),
            Err(Error::Timeout(..))
        ));
    }

    #[test]
    fn many_proxies_one_future() {
        let store = Store::memory("fut");
        let fut: ProxyFuture<u32> = store.future();
        let proxies: Vec<Proxy<u32>> = (0..8).map(|_| fut.proxy()).collect();
        fut.set_result(&7).unwrap();
        for p in proxies {
            assert_eq!(*p.resolve().unwrap(), 7);
        }
    }

    #[test]
    fn concurrent_producers_get_exactly_one_winner() {
        // The TOCTOU regression test: N producers race set_result on one
        // future; the conditional write must admit exactly one.
        let store = Store::memory("fut-race");
        let fut: ProxyFuture<u64> = store.future();
        let wins: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let f = fut.clone();
                    s.spawn(move || f.set_result(&(i as u64)).is_ok())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(wins.iter().filter(|&&w| w).count(), 1);
        let winner = wins.iter().position(|&w| w).unwrap() as u64;
        assert_eq!(fut.result(None).unwrap(), winner);
    }

    #[test]
    fn result_async_overlaps_with_compute() {
        let store = Store::memory("fut-async");
        let fut: ProxyFuture<String> = store.future();
        let pending = fut.result_async().unwrap();
        assert!(!pending.is_complete());
        assert_eq!(pending.wait_timeout(Duration::from_millis(10)).unwrap(), None);
        fut.set_result(&"pushed".to_string()).unwrap();
        assert_eq!(pending.wait().unwrap(), "pushed");
        // The value moved out: a second take errors instead of hanging.
        assert!(pending.wait().is_err());
    }

    #[test]
    fn when_all_parks_until_every_producer_fires() {
        let store = Store::memory("fut-all");
        let futs: Vec<ProxyFuture<u64>> =
            (0..6).map(|_| store.future()).collect();
        let producers: Vec<_> = futs
            .iter()
            .enumerate()
            .map(|(i, f)| {
                let f = f.clone();
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(10 + 5 * i as u64));
                    f.set_result(&(i as u64 * 3)).unwrap();
                })
            })
            .collect();
        let got = when_all(&futs, Some(Duration::from_secs(10))).unwrap();
        assert_eq!(got, vec![0, 3, 6, 9, 12, 15]);
        for p in producers {
            p.join().unwrap();
        }
        // Timeout path: an unresolved member times the join out.
        let futs: Vec<ProxyFuture<u64>> =
            (0..2).map(|_| store.future()).collect();
        futs[0].set_result(&1).unwrap();
        assert!(matches!(
            when_all(&futs, Some(Duration::from_millis(40))),
            Err(Error::Timeout(..))
        ));
        // Empty set resolves trivially.
        assert!(when_all::<u64>(&[], None).unwrap().is_empty());
    }

    #[test]
    fn when_any_returns_first_resolved_index() {
        let store = Store::memory("fut-any");
        let futs: Vec<ProxyFuture<String>> =
            (0..5).map(|_| store.future()).collect();
        let f3 = futs[3].clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            f3.set_result(&"third".to_string()).unwrap();
        });
        let (i, v) = when_any(&futs, Some(Duration::from_secs(10))).unwrap();
        assert_eq!((i, v.as_str()), (3, "third"));
        // Already-resolved member wins instantly.
        let (i, _) = when_any(&futs, None).unwrap();
        assert_eq!(i, 3);
        // Timeout and empty-set errors.
        let cold: Vec<ProxyFuture<String>> =
            (0..2).map(|_| store.future()).collect();
        assert!(matches!(
            when_any(&cold, Some(Duration::from_millis(30))),
            Err(Error::Timeout(..))
        ));
        assert!(when_any::<String>(&[], None).is_err());
    }
}
