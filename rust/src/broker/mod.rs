//! Message-broker substrate (Kafka stand-in), partition-aware.
//!
//! ProxyStream needs a low-latency event channel that is decoupled from
//! bulk data. The paper evaluates Kafka, Redis pub/sub and ZeroMQ shims;
//! the redis-sim pub/sub and queue modes live in [`crate::kv`], and this
//! module provides the Kafka-like third option: durable append-only topic
//! logs with offset-based consumption and consumer-group commits, usable
//! embedded ([`BrokerState`]) or over TCP ([`BrokerServer`]/
//! [`BrokerClient`]).
//!
//! **Partitioned topology.** A topic is a set of numbered partitions,
//! each an independent append-only log with its own offset space; the
//! classic single-log ops address partition 0. The partition is the unit
//! of both ordering and placement: entries within one partition are
//! totally ordered, and [`fabric`] spreads a topic's partitions across N
//! broker instances with the same consistent-hash ring the sharded store
//! uses ([`crate::shard::ring`]), so event throughput scales with broker
//! count instead of being serialized through one instance. A
//! [`PartitionedProducer`] routes by key hash (per-key ordering) or
//! round-robin; a [`PartitionedConsumer`] owns a deterministic slice of
//! the partition space for its consumer group and fans in fetches across
//! instances, batching all partitions co-located on one instance into a
//! single `FetchMany` frame.
//!
//! Semantics: per-partition total order, at-least-once delivery with
//! consumer committed offsets per `(group, topic, partition)`, blocking
//! fetch with timeout (long poll).

pub mod fabric;
mod server;
mod state;

pub use fabric::{
    assign_partitions, BrokerFabric, PartitionBroker, PartitionedConsumer,
    PartitionedProducer, Partitioner, ThrottledBroker,
};
pub use server::{BrokerClient, BrokerServer};
pub use state::{BrokerState, FetchReq, LogEntry};

use crate::codec::{Bytes, Decode, Encode, Reader, get_varint, put_varint};
use crate::error::{Error, Result};

/// Broker wire requests.
#[derive(Debug, Clone, PartialEq)]
pub enum BrokerRequest {
    /// Append to a topic (partition 0); replies `Offset`.
    Produce { topic: String, payload: Bytes },
    /// Fetch up to `max` entries of partition 0 starting at `offset`,
    /// waiting up to `timeout_ms` for at least one (0 = no wait).
    Fetch { topic: String, offset: u64, max: u32, timeout_ms: u64 },
    /// Commit a consumer-group offset (partition 0).
    Commit { group: String, topic: String, offset: u64 },
    /// Read a committed offset (partition 0); replies `Offset` (0 if none).
    Committed { group: String, topic: String },
    /// Current end-of-log offset of partition 0; replies `Offset`.
    EndOffset { topic: String },
    /// List topic names.
    Topics,
    Ping,
    /// Append to a specific partition; replies `Offset`.
    ProducePart { topic: String, partition: u32, payload: Bytes },
    /// Batched append to one partition; replies `Offsets`.
    ProduceMany { topic: String, partition: u32, payloads: Vec<Bytes> },
    /// Fetch from a specific partition; replies `Entries`.
    FetchPart {
        topic: String,
        partition: u32,
        offset: u64,
        max: u32,
        timeout_ms: u64,
    },
    /// Multi-partition fetch (one frame for a consumer's whole local
    /// assignment); replies `Batches` aligned with `reqs`.
    FetchMany { reqs: Vec<FetchReq>, timeout_ms: u64 },
    /// Commit a consumer-group offset on a partition.
    CommitPart { group: String, topic: String, partition: u32, offset: u64 },
    /// Read a committed partition offset; replies `Offset` (0 if none).
    CommittedPart { group: String, topic: String, partition: u32 },
    /// Current end-of-log offset of a partition; replies `Offset`.
    EndOffsetPart { topic: String, partition: u32 },
    /// Non-empty partitions of a topic; replies `PartitionList`.
    Partitions { topic: String },
    /// Scrape this process's telemetry registry; replies `Telemetry`
    /// carrying an encoded [`TelemetrySnapshot`](crate::metrics::TelemetrySnapshot).
    TelemetrySnap,
}

impl BrokerRequest {
    /// Stable op label for metrics and the slow-op log.
    pub fn name(&self) -> &'static str {
        match self {
            BrokerRequest::Produce { .. } => "produce",
            BrokerRequest::Fetch { .. } => "fetch",
            BrokerRequest::Commit { .. } => "commit",
            BrokerRequest::Committed { .. } => "committed",
            BrokerRequest::EndOffset { .. } => "end_offset",
            BrokerRequest::Topics => "topics",
            BrokerRequest::Ping => "ping",
            BrokerRequest::ProducePart { .. } => "produce_part",
            BrokerRequest::ProduceMany { .. } => "produce_many",
            BrokerRequest::FetchPart { .. } => "fetch_part",
            BrokerRequest::FetchMany { .. } => "fetch_many",
            BrokerRequest::CommitPart { .. } => "commit_part",
            BrokerRequest::CommittedPart { .. } => "committed_part",
            BrokerRequest::EndOffsetPart { .. } => "end_offset_part",
            BrokerRequest::Partitions { .. } => "partitions",
            BrokerRequest::TelemetrySnap => "telemetry",
        }
    }
}

/// Broker wire replies.
#[derive(Debug, Clone, PartialEq)]
pub enum BrokerResponse {
    Ok,
    Offset(u64),
    Entries(Vec<LogEntry>),
    TopicList(Vec<String>),
    Error(String),
    /// Batched produce result, aligned with the request payloads.
    Offsets(Vec<u64>),
    /// Multi-partition fetch result, aligned with the request.
    Batches(Vec<Vec<LogEntry>>),
    PartitionList(Vec<u32>),
    /// Encoded [`TelemetrySnapshot`](crate::metrics::TelemetrySnapshot)
    /// (opaque bytes keep the broker protocol decoupled from the
    /// snapshot codec's evolution).
    Telemetry { data: Bytes },
}

impl Encode for LogEntry {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.offset.encode(buf);
        self.payload.encode(buf);
    }
}
impl Decode for LogEntry {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(LogEntry {
            offset: Decode::decode(r)?,
            payload: Decode::decode(r)?,
        })
    }
}

impl Encode for BrokerRequest {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            BrokerRequest::Produce { topic, payload } => {
                put_varint(buf, 0);
                topic.encode(buf);
                payload.encode(buf);
            }
            BrokerRequest::Fetch { topic, offset, max, timeout_ms } => {
                put_varint(buf, 1);
                topic.encode(buf);
                offset.encode(buf);
                max.encode(buf);
                timeout_ms.encode(buf);
            }
            BrokerRequest::Commit { group, topic, offset } => {
                put_varint(buf, 2);
                group.encode(buf);
                topic.encode(buf);
                offset.encode(buf);
            }
            BrokerRequest::Committed { group, topic } => {
                put_varint(buf, 3);
                group.encode(buf);
                topic.encode(buf);
            }
            BrokerRequest::EndOffset { topic } => {
                put_varint(buf, 4);
                topic.encode(buf);
            }
            BrokerRequest::Topics => put_varint(buf, 5),
            BrokerRequest::Ping => put_varint(buf, 6),
            BrokerRequest::ProducePart { topic, partition, payload } => {
                put_varint(buf, 7);
                topic.encode(buf);
                partition.encode(buf);
                payload.encode(buf);
            }
            BrokerRequest::ProduceMany { topic, partition, payloads } => {
                put_varint(buf, 8);
                topic.encode(buf);
                partition.encode(buf);
                payloads.encode(buf);
            }
            BrokerRequest::FetchPart {
                topic,
                partition,
                offset,
                max,
                timeout_ms,
            } => {
                put_varint(buf, 9);
                topic.encode(buf);
                partition.encode(buf);
                offset.encode(buf);
                max.encode(buf);
                timeout_ms.encode(buf);
            }
            BrokerRequest::FetchMany { reqs, timeout_ms } => {
                put_varint(buf, 10);
                reqs.encode(buf);
                timeout_ms.encode(buf);
            }
            BrokerRequest::CommitPart { group, topic, partition, offset } => {
                put_varint(buf, 11);
                group.encode(buf);
                topic.encode(buf);
                partition.encode(buf);
                offset.encode(buf);
            }
            BrokerRequest::CommittedPart { group, topic, partition } => {
                put_varint(buf, 12);
                group.encode(buf);
                topic.encode(buf);
                partition.encode(buf);
            }
            BrokerRequest::EndOffsetPart { topic, partition } => {
                put_varint(buf, 13);
                topic.encode(buf);
                partition.encode(buf);
            }
            BrokerRequest::Partitions { topic } => {
                put_varint(buf, 14);
                topic.encode(buf);
            }
            BrokerRequest::TelemetrySnap => put_varint(buf, 15),
        }
    }
}

impl Decode for BrokerRequest {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => BrokerRequest::Produce {
                topic: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            1 => BrokerRequest::Fetch {
                topic: Decode::decode(r)?,
                offset: Decode::decode(r)?,
                max: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            2 => BrokerRequest::Commit {
                group: Decode::decode(r)?,
                topic: Decode::decode(r)?,
                offset: Decode::decode(r)?,
            },
            3 => BrokerRequest::Committed {
                group: Decode::decode(r)?,
                topic: Decode::decode(r)?,
            },
            4 => BrokerRequest::EndOffset { topic: Decode::decode(r)? },
            5 => BrokerRequest::Topics,
            6 => BrokerRequest::Ping,
            7 => BrokerRequest::ProducePart {
                topic: Decode::decode(r)?,
                partition: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            8 => BrokerRequest::ProduceMany {
                topic: Decode::decode(r)?,
                partition: Decode::decode(r)?,
                payloads: Decode::decode(r)?,
            },
            9 => BrokerRequest::FetchPart {
                topic: Decode::decode(r)?,
                partition: Decode::decode(r)?,
                offset: Decode::decode(r)?,
                max: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            10 => BrokerRequest::FetchMany {
                reqs: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            11 => BrokerRequest::CommitPart {
                group: Decode::decode(r)?,
                topic: Decode::decode(r)?,
                partition: Decode::decode(r)?,
                offset: Decode::decode(r)?,
            },
            12 => BrokerRequest::CommittedPart {
                group: Decode::decode(r)?,
                topic: Decode::decode(r)?,
                partition: Decode::decode(r)?,
            },
            13 => BrokerRequest::EndOffsetPart {
                topic: Decode::decode(r)?,
                partition: Decode::decode(r)?,
            },
            14 => BrokerRequest::Partitions { topic: Decode::decode(r)? },
            15 => BrokerRequest::TelemetrySnap,
            t => {
                return Err(Error::Protocol(format!("bad broker req tag {t}")))
            }
        })
    }
}

impl Encode for BrokerResponse {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            BrokerResponse::Ok => put_varint(buf, 0),
            BrokerResponse::Offset(v) => {
                put_varint(buf, 1);
                v.encode(buf);
            }
            BrokerResponse::Entries(v) => {
                put_varint(buf, 2);
                v.encode(buf);
            }
            BrokerResponse::TopicList(v) => {
                put_varint(buf, 3);
                v.encode(buf);
            }
            BrokerResponse::Error(msg) => {
                put_varint(buf, 4);
                msg.encode(buf);
            }
            BrokerResponse::Offsets(v) => {
                put_varint(buf, 5);
                v.encode(buf);
            }
            BrokerResponse::Batches(v) => {
                put_varint(buf, 6);
                v.encode(buf);
            }
            BrokerResponse::PartitionList(v) => {
                put_varint(buf, 7);
                v.encode(buf);
            }
            BrokerResponse::Telemetry { data } => {
                put_varint(buf, 8);
                data.encode(buf);
            }
        }
    }
}

impl Decode for BrokerResponse {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => BrokerResponse::Ok,
            1 => BrokerResponse::Offset(Decode::decode(r)?),
            2 => BrokerResponse::Entries(Decode::decode(r)?),
            3 => BrokerResponse::TopicList(Decode::decode(r)?),
            4 => BrokerResponse::Error(Decode::decode(r)?),
            5 => BrokerResponse::Offsets(Decode::decode(r)?),
            6 => BrokerResponse::Batches(Decode::decode(r)?),
            7 => BrokerResponse::PartitionList(Decode::decode(r)?),
            8 => BrokerResponse::Telemetry { data: Decode::decode(r)? },
            t => {
                return Err(Error::Protocol(format!("bad broker resp tag {t}")))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broker_frames_roundtrip() {
        for req in [
            BrokerRequest::Produce {
                topic: "t".into(),
                payload: Bytes(vec![1, 2]),
            },
            BrokerRequest::Fetch {
                topic: "t".into(),
                offset: 42,
                max: 10,
                timeout_ms: 100,
            },
            BrokerRequest::Commit {
                group: "g".into(),
                topic: "t".into(),
                offset: 7,
            },
            BrokerRequest::Committed { group: "g".into(), topic: "t".into() },
            BrokerRequest::EndOffset { topic: "t".into() },
            BrokerRequest::Topics,
            BrokerRequest::Ping,
            BrokerRequest::ProducePart {
                topic: "t".into(),
                partition: 3,
                payload: Bytes(vec![5]),
            },
            BrokerRequest::ProduceMany {
                topic: "t".into(),
                partition: 1,
                payloads: vec![Bytes(vec![1]), Bytes(Vec::new())],
            },
            BrokerRequest::FetchPart {
                topic: "t".into(),
                partition: 2,
                offset: 9,
                max: 4,
                timeout_ms: 50,
            },
            BrokerRequest::FetchMany {
                reqs: vec![("t".into(), 0, 1, 8), ("u".into(), 5, 0, 1)],
                timeout_ms: 250,
            },
            BrokerRequest::CommitPart {
                group: "g".into(),
                topic: "t".into(),
                partition: 6,
                offset: 11,
            },
            BrokerRequest::CommittedPart {
                group: "g".into(),
                topic: "t".into(),
                partition: 6,
            },
            BrokerRequest::EndOffsetPart { topic: "t".into(), partition: 1 },
            BrokerRequest::Partitions { topic: "t".into() },
            BrokerRequest::TelemetrySnap,
        ] {
            let back = BrokerRequest::from_bytes(&req.to_bytes()).unwrap();
            assert_eq!(req, back);
        }
        for resp in [
            BrokerResponse::Ok,
            BrokerResponse::Offset(9),
            BrokerResponse::Entries(vec![LogEntry {
                offset: 1,
                payload: Bytes(vec![3]),
            }]),
            BrokerResponse::TopicList(vec!["a".into()]),
            BrokerResponse::Error("x".into()),
            BrokerResponse::Offsets(vec![0, 1, 2]),
            BrokerResponse::Batches(vec![
                Vec::new(),
                vec![LogEntry { offset: 0, payload: Bytes(vec![4]) }],
            ]),
            BrokerResponse::PartitionList(vec![0, 3, 7]),
            BrokerResponse::Telemetry { data: Bytes(vec![1, 2, 3]) },
        ] {
            let back = BrokerResponse::from_bytes(&resp.to_bytes()).unwrap();
            assert_eq!(resp, back);
        }
    }
}
