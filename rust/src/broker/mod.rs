//! Message-broker substrate (Kafka stand-in).
//!
//! ProxyStream needs a low-latency event channel that is decoupled from
//! bulk data. The paper evaluates Kafka, Redis pub/sub and ZeroMQ shims;
//! the redis-sim pub/sub and queue modes live in [`crate::kv`], and this
//! module provides the Kafka-like third option: durable append-only topic
//! logs with offset-based consumption and consumer-group commits, usable
//! embedded ([`BrokerState`]) or over TCP ([`BrokerServer`]/
//! [`BrokerClient`]).
//!
//! Semantics: per-topic total order, at-least-once delivery with consumer
//! committed offsets, blocking fetch with timeout (long poll).

mod server;
mod state;

pub use server::{BrokerClient, BrokerServer};
pub use state::{BrokerState, LogEntry};

use crate::codec::{Bytes, Decode, Encode, Reader, get_varint, put_varint};
use crate::error::{Error, Result};

/// Broker wire requests.
#[derive(Debug, Clone, PartialEq)]
pub enum BrokerRequest {
    /// Append to a topic; replies `Offset`.
    Produce { topic: String, payload: Bytes },
    /// Fetch up to `max` entries starting at `offset`, waiting up to
    /// `timeout_ms` for at least one (0 = no wait).
    Fetch { topic: String, offset: u64, max: u32, timeout_ms: u64 },
    /// Commit a consumer-group offset.
    Commit { group: String, topic: String, offset: u64 },
    /// Read a committed offset; replies `Offset` (0 if none).
    Committed { group: String, topic: String },
    /// Current end-of-log offset; replies `Offset`.
    EndOffset { topic: String },
    /// List topic names.
    Topics,
    Ping,
}

/// Broker wire replies.
#[derive(Debug, Clone, PartialEq)]
pub enum BrokerResponse {
    Ok,
    Offset(u64),
    Entries(Vec<LogEntry>),
    TopicList(Vec<String>),
    Error(String),
}

impl Encode for LogEntry {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.offset.encode(buf);
        self.payload.encode(buf);
    }
}
impl Decode for LogEntry {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(LogEntry {
            offset: Decode::decode(r)?,
            payload: Decode::decode(r)?,
        })
    }
}

impl Encode for BrokerRequest {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            BrokerRequest::Produce { topic, payload } => {
                put_varint(buf, 0);
                topic.encode(buf);
                payload.encode(buf);
            }
            BrokerRequest::Fetch { topic, offset, max, timeout_ms } => {
                put_varint(buf, 1);
                topic.encode(buf);
                offset.encode(buf);
                max.encode(buf);
                timeout_ms.encode(buf);
            }
            BrokerRequest::Commit { group, topic, offset } => {
                put_varint(buf, 2);
                group.encode(buf);
                topic.encode(buf);
                offset.encode(buf);
            }
            BrokerRequest::Committed { group, topic } => {
                put_varint(buf, 3);
                group.encode(buf);
                topic.encode(buf);
            }
            BrokerRequest::EndOffset { topic } => {
                put_varint(buf, 4);
                topic.encode(buf);
            }
            BrokerRequest::Topics => put_varint(buf, 5),
            BrokerRequest::Ping => put_varint(buf, 6),
        }
    }
}

impl Decode for BrokerRequest {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => BrokerRequest::Produce {
                topic: Decode::decode(r)?,
                payload: Decode::decode(r)?,
            },
            1 => BrokerRequest::Fetch {
                topic: Decode::decode(r)?,
                offset: Decode::decode(r)?,
                max: Decode::decode(r)?,
                timeout_ms: Decode::decode(r)?,
            },
            2 => BrokerRequest::Commit {
                group: Decode::decode(r)?,
                topic: Decode::decode(r)?,
                offset: Decode::decode(r)?,
            },
            3 => BrokerRequest::Committed {
                group: Decode::decode(r)?,
                topic: Decode::decode(r)?,
            },
            4 => BrokerRequest::EndOffset { topic: Decode::decode(r)? },
            5 => BrokerRequest::Topics,
            6 => BrokerRequest::Ping,
            t => {
                return Err(Error::Protocol(format!("bad broker req tag {t}")))
            }
        })
    }
}

impl Encode for BrokerResponse {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            BrokerResponse::Ok => put_varint(buf, 0),
            BrokerResponse::Offset(v) => {
                put_varint(buf, 1);
                v.encode(buf);
            }
            BrokerResponse::Entries(v) => {
                put_varint(buf, 2);
                v.encode(buf);
            }
            BrokerResponse::TopicList(v) => {
                put_varint(buf, 3);
                v.encode(buf);
            }
            BrokerResponse::Error(msg) => {
                put_varint(buf, 4);
                msg.encode(buf);
            }
        }
    }
}

impl Decode for BrokerResponse {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(match get_varint(r)? {
            0 => BrokerResponse::Ok,
            1 => BrokerResponse::Offset(Decode::decode(r)?),
            2 => BrokerResponse::Entries(Decode::decode(r)?),
            3 => BrokerResponse::TopicList(Decode::decode(r)?),
            4 => BrokerResponse::Error(Decode::decode(r)?),
            t => {
                return Err(Error::Protocol(format!("bad broker resp tag {t}")))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broker_frames_roundtrip() {
        for req in [
            BrokerRequest::Produce {
                topic: "t".into(),
                payload: Bytes(vec![1, 2]),
            },
            BrokerRequest::Fetch {
                topic: "t".into(),
                offset: 42,
                max: 10,
                timeout_ms: 100,
            },
            BrokerRequest::Commit {
                group: "g".into(),
                topic: "t".into(),
                offset: 7,
            },
            BrokerRequest::Committed { group: "g".into(), topic: "t".into() },
            BrokerRequest::EndOffset { topic: "t".into() },
            BrokerRequest::Topics,
            BrokerRequest::Ping,
        ] {
            let back = BrokerRequest::from_bytes(&req.to_bytes()).unwrap();
            assert_eq!(req, back);
        }
        for resp in [
            BrokerResponse::Ok,
            BrokerResponse::Offset(9),
            BrokerResponse::Entries(vec![LogEntry {
                offset: 1,
                payload: Bytes(vec![3]),
            }]),
            BrokerResponse::TopicList(vec!["a".into()]),
            BrokerResponse::Error("x".into()),
        ] {
            let back = BrokerResponse::from_bytes(&resp.to_bytes()).unwrap();
            assert_eq!(resp, back);
        }
    }
}
