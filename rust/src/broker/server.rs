//! TCP front-end + client for the broker engine.
//!
//! Like the KV server, the broker spawns through the unified
//! [`ServerBuilder`] with two ingress modes: event-driven (default on
//! Linux — an epoll reactor pool multiplexing every consumer) and
//! thread-per-connection. Long-poll fetches never park a loop thread:
//! the service *probes* with a zero timeout (fetch is read-only, so the
//! probe is free) and defers only genuinely empty polls to a helper
//! thread that completes through the connection's [`ConnHandle`].

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::codec::{Bytes, Decode, Encode};
use crate::error::{Error, Result};
use crate::kv::{read_frame, write_frame};
use crate::metrics::telemetry;
use crate::metrics::TelemetrySnapshot;
use crate::net::{
    ConnHandle, EventLoopPool, FrameOutcome, Ingress, NoState, ServerBuilder,
    Service,
};

use super::state::{BrokerState, FetchReq, LogEntry};
use super::{BrokerRequest, BrokerResponse};

/// Cached registry handles for the broker's hot-path metrics.
struct BrokerMetrics {
    connections: Arc<telemetry::Gauge>,
    op_us: Arc<telemetry::Histogram>,
}

fn broker_metrics() -> &'static BrokerMetrics {
    static M: OnceLock<BrokerMetrics> = OnceLock::new();
    M.get_or_init(|| BrokerMetrics {
        connections: telemetry::gauge("broker.server.connections"),
        op_us: telemetry::histogram("broker.server.op_us"),
    })
}

/// The running ingress machinery behind a [`BrokerServer`].
enum IngressHandle {
    Threaded {
        accept_thread: Option<std::thread::JoinHandle<()>>,
        /// Live connection sockets, force-closed on shutdown.
        conns: Arc<Mutex<Vec<TcpStream>>>,
    },
    Event(EventLoopPool),
}

/// A running broker server. Dropping the handle shuts it down.
pub struct BrokerServer {
    pub addr: SocketAddr,
    state: BrokerState,
    stop: Arc<AtomicBool>,
    ingress: IngressHandle,
    /// The HTTP admin plane, when the builder asked for one.
    admin: Option<EventLoopPool>,
}

impl BrokerServer {
    /// Bind to 127.0.0.1 on an ephemeral port and start serving.
    #[deprecated(note = "use ServerBuilder::new().spawn_broker()")]
    pub fn spawn() -> Result<BrokerServer> {
        ServerBuilder::new().spawn_broker()
    }

    /// Serve an externally created state.
    #[deprecated(note = "use ServerBuilder::new().with_state(state).spawn()")]
    pub fn spawn_with_state(state: BrokerState) -> Result<BrokerServer> {
        ServerBuilder::new().with_state(state).spawn()
    }

    pub fn state(&self) -> &BrokerState {
        &self.state
    }

    /// Where the HTTP admin plane listens, when one was requested via
    /// [`ServerBuilder::admin_addr`].
    pub fn admin_addr(&self) -> Option<SocketAddr> {
        self.admin.as_ref().map(|p| p.addr)
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(pool) = &mut self.admin {
            pool.shutdown();
        }
        match &mut self.ingress {
            IngressHandle::Threaded { accept_thread, conns } => {
                // Unblock the blocking accept; the loop re-checks `stop`.
                let _ = TcpStream::connect(self.addr);
                for conn in conns.lock().unwrap().drain(..) {
                    let _ = conn.shutdown(std::net::Shutdown::Both);
                }
                if let Some(h) = accept_thread.take() {
                    let _ = h.join();
                }
            }
            IngressHandle::Event(pool) => pool.shutdown(),
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl ServerBuilder<BrokerState> {
    /// Spawn a broker server serving this builder's state.
    pub fn spawn(self) -> Result<BrokerServer> {
        spawn_broker_server(self)
    }
}

impl ServerBuilder<NoState> {
    /// Spawn a broker server with fresh state — or, when
    /// [`ServerBuilder::data_dir`] / `durability` was set, a durable
    /// broker recovered from that directory (per-partition log replay +
    /// commit checkpoint).
    pub fn spawn_broker(self) -> Result<BrokerServer> {
        let state = match &self.durability {
            Some(opts) => BrokerState::open_durable(opts)?,
            None => BrokerState::new(),
        };
        self.with_state(state).spawn()
    }
}

fn spawn_broker_server(b: ServerBuilder<BrokerState>) -> Result<BrokerServer> {
    let stop = Arc::new(AtomicBool::new(false));
    // Spawned first so a bad admin address fails the whole spawn before
    // any data-plane thread starts.
    let admin = match b.admin {
        Some(addr) => Some(crate::net::http::spawn_admin(
            addr,
            "broker",
            Arc::new(|| broker_metrics().connections.get().max(0) as usize),
        )?),
        None => None,
    };
    match b.ingress {
        Ingress::EventLoop => {
            let service =
                Arc::new(BrokerEventService { state: b.state.clone() });
            let pool = EventLoopPool::spawn(
                b.bind,
                b.event_loops,
                b.max_connections,
                service,
                "broker",
            )?;
            Ok(BrokerServer {
                addr: pool.addr,
                state: b.state,
                stop,
                ingress: IngressHandle::Event(pool),
                admin,
            })
        }
        Ingress::Threaded => spawn_threaded(b, stop, admin),
    }
}

fn spawn_threaded(
    b: ServerBuilder<BrokerState>,
    stop: Arc<AtomicBool>,
    admin: Option<EventLoopPool>,
) -> Result<BrokerServer> {
    let listener = TcpListener::bind(b.bind)?;
    let addr = listener.local_addr()?;
    let state = b.state;
    let max_connections = b.max_connections;
    let stop2 = stop.clone();
    let state2 = state.clone();
    let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let conns2 = conns.clone();
    let active = Arc::new(AtomicUsize::new(0));
    // Blocking accept (no busy-wait): `shutdown` sets the stop flag and
    // pokes the listener with a throwaway connection to unblock it.
    let accept_thread = std::thread::Builder::new()
        .name(format!("broker-accept-{}", addr.port()))
        .spawn(move || loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    if max_connections > 0
                        && active.load(Ordering::Relaxed) >= max_connections
                    {
                        drop(stream); // over the cap
                        continue;
                    }
                    active.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = stream.try_clone() {
                        conns2.lock().unwrap().push(clone);
                    }
                    let st = state2.clone();
                    let active2 = active.clone();
                    std::thread::Builder::new()
                        .name("broker-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(stream, st);
                            active2.fetch_sub(1, Ordering::Relaxed);
                        })
                        .expect("spawn broker-conn");
                }
                Err(_) => {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
        })
        .expect("spawn broker-accept");
    Ok(BrokerServer {
        addr,
        state,
        stop,
        ingress: IngressHandle::Threaded {
            accept_thread: Some(accept_thread),
            conns,
        },
        admin,
    })
}

/// Execute one broker request against the engine (the shared core of
/// both ingress modes). Fetches block up to their own timeout — callers
/// that must not park (the event loop) probe first and defer.
fn handle_broker_request(
    state: &BrokerState,
    req: BrokerRequest,
) -> BrokerResponse {
    match req {
        BrokerRequest::Produce { topic, payload } => {
            BrokerResponse::Offset(state.produce(&topic, payload))
        }
        BrokerRequest::Fetch { topic, offset, max, timeout_ms } => {
            BrokerResponse::Entries(state.fetch(
                &topic,
                offset,
                max,
                Duration::from_millis(timeout_ms),
            ))
        }
        BrokerRequest::Commit { group, topic, offset } => {
            state.commit(&group, &topic, offset);
            BrokerResponse::Ok
        }
        BrokerRequest::Committed { group, topic } => {
            BrokerResponse::Offset(state.committed(&group, &topic))
        }
        BrokerRequest::EndOffset { topic } => {
            BrokerResponse::Offset(state.end_offset(&topic))
        }
        BrokerRequest::Topics => BrokerResponse::TopicList(state.topics()),
        BrokerRequest::Ping => BrokerResponse::Ok,
        BrokerRequest::ProducePart { topic, partition, payload } => {
            BrokerResponse::Offset(state.produce_to(&topic, partition, payload))
        }
        BrokerRequest::ProduceMany { topic, partition, payloads } => {
            BrokerResponse::Offsets(state.produce_many(
                &topic, partition, payloads,
            ))
        }
        BrokerRequest::FetchPart { topic, partition, offset, max, timeout_ms } => {
            BrokerResponse::Entries(state.fetch_from(
                &topic,
                partition,
                offset,
                max,
                Duration::from_millis(timeout_ms),
            ))
        }
        BrokerRequest::FetchMany { reqs, timeout_ms } => {
            BrokerResponse::Batches(
                state.fetch_many(&reqs, Duration::from_millis(timeout_ms)),
            )
        }
        BrokerRequest::CommitPart { group, topic, partition, offset } => {
            state.commit_part(&group, &topic, partition, offset);
            BrokerResponse::Ok
        }
        BrokerRequest::CommittedPart { group, topic, partition } => {
            BrokerResponse::Offset(state.committed_part(
                &group, &topic, partition,
            ))
        }
        BrokerRequest::EndOffsetPart { topic, partition } => {
            BrokerResponse::Offset(state.end_offset_of(&topic, partition))
        }
        BrokerRequest::Partitions { topic } => {
            BrokerResponse::PartitionList(state.partitions(&topic))
        }
        BrokerRequest::TelemetrySnap => BrokerResponse::Telemetry {
            data: Bytes(telemetry::snapshot().to_bytes()),
        },
    }
}

/// Execute one broker request, recording op latency and feeding the
/// slow-op log (the shared wrapper of both ingress modes' full-op
/// paths; zero-timeout probes stay un-instrumented).
fn respond(state: &BrokerState, req: BrokerRequest) -> BrokerResponse {
    let name = req.name();
    let start = Instant::now();
    let resp = handle_broker_request(state, req);
    let dur = start.elapsed();
    broker_metrics().op_us.record_duration(dur);
    telemetry::record_slow_op(name, dur, 0, 0, "broker");
    resp
}

/// Broker protocol logic on the reactor.
struct BrokerEventService {
    state: BrokerState,
}

impl BrokerEventService {
    /// Run a long-poll fetch on a helper thread; the reply re-enters the
    /// loop via [`ConnHandle::complete`].
    fn defer(&self, conn: &ConnHandle, req: BrokerRequest) -> FrameOutcome {
        let state = self.state.clone();
        let handle = conn.clone();
        let spawned = std::thread::Builder::new()
            .name("broker-park".into())
            .spawn(move || {
                let resp = respond(&state, req);
                handle.complete(resp.to_bytes());
            });
        match spawned {
            Ok(_) => FrameOutcome::Deferred,
            Err(_) => FrameOutcome::Close,
        }
    }
}

impl Service for BrokerEventService {
    fn on_open(&self, _conn: &ConnHandle) {
        broker_metrics().connections.add(1);
    }

    fn on_close(&self, _conn_id: u64) {
        broker_metrics().connections.add(-1);
    }

    fn on_frame(&self, conn: &ConnHandle, body: Vec<u8>) -> FrameOutcome {
        let req = match BrokerRequest::from_bytes(&body) {
            Ok(req) => req,
            Err(_) => return FrameOutcome::Close,
        };
        // Fetches are read-only, so a zero-timeout probe answers
        // non-empty polls inline; only an empty long poll pays for a
        // parked helper thread.
        match req {
            BrokerRequest::Fetch { topic, offset, max, timeout_ms } => {
                let entries =
                    self.state.fetch(&topic, offset, max, Duration::ZERO);
                if !entries.is_empty() || timeout_ms == 0 {
                    return FrameOutcome::Reply(
                        BrokerResponse::Entries(entries).to_bytes().into(),
                    );
                }
                self.defer(
                    conn,
                    BrokerRequest::Fetch { topic, offset, max, timeout_ms },
                )
            }
            BrokerRequest::FetchPart {
                topic,
                partition,
                offset,
                max,
                timeout_ms,
            } => {
                let entries = self.state.fetch_from(
                    &topic,
                    partition,
                    offset,
                    max,
                    Duration::ZERO,
                );
                if !entries.is_empty() || timeout_ms == 0 {
                    return FrameOutcome::Reply(
                        BrokerResponse::Entries(entries).to_bytes().into(),
                    );
                }
                self.defer(
                    conn,
                    BrokerRequest::FetchPart {
                        topic,
                        partition,
                        offset,
                        max,
                        timeout_ms,
                    },
                )
            }
            BrokerRequest::FetchMany { reqs, timeout_ms } => {
                let batches = self.state.fetch_many(&reqs, Duration::ZERO);
                if batches.iter().any(|b| !b.is_empty()) || timeout_ms == 0 {
                    return FrameOutcome::Reply(
                        BrokerResponse::Batches(batches).to_bytes().into(),
                    );
                }
                self.defer(conn, BrokerRequest::FetchMany { reqs, timeout_ms })
            }
            other => FrameOutcome::Reply(
                respond(&self.state, other).to_bytes().into(),
            ),
        }
    }
}

fn serve_connection(stream: TcpStream, state: BrokerState) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader =
        std::io::BufReader::with_capacity(1 << 18, stream.try_clone()?);
    let mut writer = std::io::BufWriter::with_capacity(1 << 18, stream);
    broker_metrics().connections.add(1);
    let result = (|| loop {
        let req: Option<BrokerRequest> = read_frame(&mut reader)?;
        let Some(req) = req else { return Ok(()) };
        let resp = respond(&state, req);
        write_frame(&mut writer, &resp)?;
    })();
    broker_metrics().connections.add(-1);
    result
}

/// Blocking broker client (one request in flight).
pub struct BrokerClient {
    conn: Mutex<(
        std::io::BufReader<TcpStream>,
        std::io::BufWriter<TcpStream>,
    )>,
    pub addr: SocketAddr,
}

impl BrokerClient {
    pub fn connect(addr: SocketAddr) -> Result<BrokerClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(BrokerClient {
            conn: Mutex::new((
                std::io::BufReader::with_capacity(1 << 18, stream.try_clone()?),
                std::io::BufWriter::with_capacity(1 << 18, stream),
            )),
            addr,
        })
    }

    fn call(&self, req: BrokerRequest) -> Result<BrokerResponse> {
        let mut conn = self.conn.lock().unwrap();
        write_frame(&mut conn.1, &req)?;
        match read_frame::<_, BrokerResponse>(&mut conn.0)? {
            Some(BrokerResponse::Error(msg)) => Err(Error::Protocol(msg)),
            Some(resp) => Ok(resp),
            None => Err(Error::Connector("broker closed connection".into())),
        }
    }

    pub fn ping(&self) -> Result<()> {
        match self.call(BrokerRequest::Ping)? {
            BrokerResponse::Ok => Ok(()),
            other => Err(Error::Protocol(format!("bad ping reply {other:?}"))),
        }
    }

    pub fn produce(&self, topic: &str, payload: Bytes) -> Result<u64> {
        match self.call(BrokerRequest::Produce { topic: topic.into(), payload })? {
            BrokerResponse::Offset(o) => Ok(o),
            other => Err(Error::Protocol(format!("bad produce reply {other:?}"))),
        }
    }

    pub fn fetch(
        &self,
        topic: &str,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>> {
        match self.call(BrokerRequest::Fetch {
            topic: topic.into(),
            offset,
            max,
            timeout_ms: timeout.as_millis() as u64,
        })? {
            BrokerResponse::Entries(v) => Ok(v),
            other => Err(Error::Protocol(format!("bad fetch reply {other:?}"))),
        }
    }

    pub fn commit(&self, group: &str, topic: &str, offset: u64) -> Result<()> {
        match self.call(BrokerRequest::Commit {
            group: group.into(),
            topic: topic.into(),
            offset,
        })? {
            BrokerResponse::Ok => Ok(()),
            other => Err(Error::Protocol(format!("bad commit reply {other:?}"))),
        }
    }

    pub fn committed(&self, group: &str, topic: &str) -> Result<u64> {
        match self.call(BrokerRequest::Committed {
            group: group.into(),
            topic: topic.into(),
        })? {
            BrokerResponse::Offset(o) => Ok(o),
            other => {
                Err(Error::Protocol(format!("bad committed reply {other:?}")))
            }
        }
    }

    pub fn end_offset(&self, topic: &str) -> Result<u64> {
        match self.call(BrokerRequest::EndOffset { topic: topic.into() })? {
            BrokerResponse::Offset(o) => Ok(o),
            other => {
                Err(Error::Protocol(format!("bad end_offset reply {other:?}")))
            }
        }
    }

    pub fn topics(&self) -> Result<Vec<String>> {
        match self.call(BrokerRequest::Topics)? {
            BrokerResponse::TopicList(v) => Ok(v),
            other => Err(Error::Protocol(format!("bad topics reply {other:?}"))),
        }
    }

    pub fn produce_to(
        &self,
        topic: &str,
        partition: u32,
        payload: Bytes,
    ) -> Result<u64> {
        match self.call(BrokerRequest::ProducePart {
            topic: topic.into(),
            partition,
            payload,
        })? {
            BrokerResponse::Offset(o) => Ok(o),
            other => Err(Error::Protocol(format!("bad produce reply {other:?}"))),
        }
    }

    /// Batched append to one partition: one frame, one lock acquisition
    /// server-side; returns the assigned offsets.
    pub fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Result<Vec<u64>> {
        match self.call(BrokerRequest::ProduceMany {
            topic: topic.into(),
            partition,
            payloads,
        })? {
            BrokerResponse::Offsets(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("bad produce_many reply {other:?}")))
            }
        }
    }

    pub fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>> {
        match self.call(BrokerRequest::FetchPart {
            topic: topic.into(),
            partition,
            offset,
            max,
            timeout_ms: timeout.as_millis() as u64,
        })? {
            BrokerResponse::Entries(v) => Ok(v),
            other => Err(Error::Protocol(format!("bad fetch reply {other:?}"))),
        }
    }

    /// Multi-partition fetch in one round trip, aligned with `reqs`.
    pub fn fetch_many(
        &self,
        reqs: &[FetchReq],
        timeout: Duration,
    ) -> Result<Vec<Vec<LogEntry>>> {
        match self.call(BrokerRequest::FetchMany {
            reqs: reqs.to_vec(),
            timeout_ms: timeout.as_millis() as u64,
        })? {
            BrokerResponse::Batches(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("bad fetch_many reply {other:?}")))
            }
        }
    }

    pub fn commit_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()> {
        match self.call(BrokerRequest::CommitPart {
            group: group.into(),
            topic: topic.into(),
            partition,
            offset,
        })? {
            BrokerResponse::Ok => Ok(()),
            other => Err(Error::Protocol(format!("bad commit reply {other:?}"))),
        }
    }

    pub fn committed_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
    ) -> Result<u64> {
        match self.call(BrokerRequest::CommittedPart {
            group: group.into(),
            topic: topic.into(),
            partition,
        })? {
            BrokerResponse::Offset(o) => Ok(o),
            other => {
                Err(Error::Protocol(format!("bad committed reply {other:?}")))
            }
        }
    }

    pub fn end_offset_of(&self, topic: &str, partition: u32) -> Result<u64> {
        match self.call(BrokerRequest::EndOffsetPart {
            topic: topic.into(),
            partition,
        })? {
            BrokerResponse::Offset(o) => Ok(o),
            other => {
                Err(Error::Protocol(format!("bad end_offset reply {other:?}")))
            }
        }
    }

    pub fn partitions(&self, topic: &str) -> Result<Vec<u32>> {
        match self.call(BrokerRequest::Partitions { topic: topic.into() })? {
            BrokerResponse::PartitionList(v) => Ok(v),
            other => {
                Err(Error::Protocol(format!("bad partitions reply {other:?}")))
            }
        }
    }

    /// Scrape the broker process's telemetry registry over the data
    /// connection.
    pub fn telemetry(&self) -> Result<TelemetrySnapshot> {
        match self.call(BrokerRequest::TelemetrySnap)? {
            BrokerResponse::Telemetry { data } => {
                TelemetrySnapshot::from_bytes(&data.0)
            }
            other => {
                Err(Error::Protocol(format!("bad telemetry reply {other:?}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_fetch_over_tcp() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let c = BrokerClient::connect(server.addr).unwrap();
        c.ping().unwrap();
        assert_eq!(c.produce("t", Bytes(vec![1])).unwrap(), 0);
        assert_eq!(c.produce("t", Bytes(vec![2])).unwrap(), 1);
        let entries = c.fetch("t", 0, 10, Duration::ZERO).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[1].payload, Bytes(vec![2]));
        assert_eq!(c.end_offset("t").unwrap(), 2);
        assert_eq!(c.topics().unwrap(), vec!["t".to_string()]);
    }

    #[test]
    fn long_poll_across_clients() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let addr = server.addr;
        let h = std::thread::spawn(move || {
            let c = BrokerClient::connect(addr).unwrap();
            c.fetch("t", 0, 1, Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let p = BrokerClient::connect(server.addr).unwrap();
        p.produce("t", Bytes(vec![7])).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].payload, Bytes(vec![7]));
    }

    #[test]
    fn threaded_ingress_produce_and_long_poll() {
        let server = ServerBuilder::new()
            .ingress(Ingress::Threaded)
            .spawn_broker()
            .unwrap();
        let addr = server.addr;
        let h = std::thread::spawn(move || {
            let c = BrokerClient::connect(addr).unwrap();
            c.fetch("t", 0, 1, Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let p = BrokerClient::connect(server.addr).unwrap();
        p.produce("t", Bytes(vec![7])).unwrap();
        assert_eq!(h.join().unwrap().len(), 1);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_spawn_shims_still_work() {
        let server = BrokerServer::spawn().unwrap();
        let c = BrokerClient::connect(server.addr).unwrap();
        c.ping().unwrap();
        let state = BrokerState::new();
        state.produce("pre", Bytes(vec![1]));
        let server2 = BrokerServer::spawn_with_state(state).unwrap();
        let c2 = BrokerClient::connect(server2.addr).unwrap();
        assert_eq!(c2.end_offset("pre").unwrap(), 1);
    }

    #[test]
    fn partitioned_ops_over_tcp() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let c = BrokerClient::connect(server.addr).unwrap();
        assert_eq!(c.produce_to("t", 2, Bytes(vec![1])).unwrap(), 0);
        assert_eq!(
            c.produce_many("t", 2, vec![Bytes(vec![2]), Bytes(vec![3])])
                .unwrap(),
            vec![1, 2]
        );
        assert_eq!(c.end_offset_of("t", 2).unwrap(), 3);
        assert_eq!(c.end_offset_of("t", 0).unwrap(), 0);
        assert_eq!(c.partitions("t").unwrap(), vec![2]);
        let entries = c
            .fetch_from("t", 2, 1, 10, Duration::ZERO)
            .unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].payload, Bytes(vec![2]));
        // Multi-partition fetch aligns with the request order.
        c.produce_to("t", 5, Bytes(vec![9])).unwrap();
        let batches = c
            .fetch_many(
                &[("t".into(), 5, 0, 10), ("t".into(), 2, 0, 1)],
                Duration::ZERO,
            )
            .unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0][0].payload, Bytes(vec![9]));
        assert_eq!(batches[1][0].payload, Bytes(vec![1]));
        // Partitioned commits round-trip and stay partition-scoped.
        c.commit_part("g", "t", 2, 3).unwrap();
        assert_eq!(c.committed_part("g", "t", 2).unwrap(), 3);
        assert_eq!(c.committed_part("g", "t", 5).unwrap(), 0);
    }

    #[test]
    fn consumer_group_commits() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let c = BrokerClient::connect(server.addr).unwrap();
        assert_eq!(c.committed("g", "t").unwrap(), 0);
        c.commit("g", "t", 3).unwrap();
        assert_eq!(c.committed("g", "t").unwrap(), 3);
    }

    #[test]
    fn multi_consumer_sees_same_order() {
        let server = ServerBuilder::new().spawn_broker().unwrap();
        let p = BrokerClient::connect(server.addr).unwrap();
        for i in 0..20u8 {
            p.produce("t", Bytes(vec![i])).unwrap();
        }
        let addr = server.addr;
        let readers: Vec<_> = (0..3)
            .map(|_| {
                std::thread::spawn(move || {
                    let c = BrokerClient::connect(addr).unwrap();
                    let mut seen = Vec::new();
                    let mut off = 0;
                    while seen.len() < 20 {
                        for e in
                            c.fetch("t", off, 7, Duration::from_secs(1)).unwrap()
                        {
                            off = e.offset + 1;
                            seen.push(e.payload.0[0]);
                        }
                    }
                    seen
                })
            })
            .collect();
        for r in readers {
            assert_eq!(r.join().unwrap(), (0..20u8).collect::<Vec<_>>());
        }
    }
}
