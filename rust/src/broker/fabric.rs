//! Partitioned broker fabric: one logical event channel spread across N
//! broker instances.
//!
//! The single-instance broker serializes every topic through one lock and
//! one endpoint — the same bottleneck the sharded store fabric
//! ([`crate::shard`]) removed from the bulk channel. This module applies
//! the identical recipe to the event channel:
//!
//! * a topic is split into P **partitions** (the unit of ordering);
//! * the consistent-hash ring ([`crate::shard::ring`]) places partition
//!   `p` of topic `t` on one of N broker **instances**, deterministically
//!   in every process that knows `(instances, partitions)`;
//! * a [`PartitionedProducer`] picks the partition by key hash (same key →
//!   same partition → per-key total order) or round-robin, and batches
//!   multi-event appends into one `ProduceMany` frame per partition;
//! * a [`PartitionedConsumer`] owns a deterministic slice of the
//!   partition space within its consumer group ([`assign_partitions`]:
//!   every partition owned by exactly one member) and fans in fetches,
//!   batching all partitions co-located on an instance into a single
//!   `FetchMany` round trip.
//!
//! Instances are anything implementing [`PartitionBroker`]: embedded
//! [`BrokerState`]s, TCP [`BrokerClient`]s, or wrappers such as
//! [`ThrottledBroker`] (benches) and
//! [`FlakyBroker`](crate::testing::fail::FlakyBroker) (failure
//! injection).

use std::collections::{HashMap, VecDeque};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Bytes;
use crate::error::{Error, Result};
use crate::metrics::telemetry;
use crate::netsim::Link;
use crate::ops::reactor::{fan_out, Job};
use crate::shard::ring::{hash_key, HashRing};

use super::server::BrokerClient;
use super::state::{BrokerState, FetchReq, LogEntry};

/// Per-instance results of a fan-in fetch round: the requests an instance
/// served and what came back.
type SweepResults = Vec<(Vec<FetchReq>, Result<Vec<Vec<LogEntry>>>)>;

/// Per-partition results of a batched produce fan-out: (input indices,
/// partition) and the offsets the instance assigned.
type ProduceResults = Vec<((Vec<usize>, u32), Result<Vec<u64>>)>;

/// Fabric-wide telemetry handles, resolved once per process.
struct BrokerMetrics {
    produce_events: Arc<telemetry::Counter>,
    fetch_events: Arc<telemetry::Counter>,
}

fn broker_metrics() -> &'static BrokerMetrics {
    static METRICS: std::sync::OnceLock<BrokerMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| BrokerMetrics {
        produce_events: telemetry::counter("broker.produce_events"),
        fetch_events: telemetry::counter("broker.fetch_events"),
    })
}

/// Partition-aware broker endpoint: the interface the fabric routes over.
pub trait PartitionBroker: Send + Sync {
    fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> Result<u64>;

    /// Batched append to one partition; offsets align with `payloads`.
    fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Result<Vec<u64>>;

    fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>>;

    /// Multi-partition fetch; results align with `reqs`.
    fn fetch_many(
        &self,
        reqs: &[FetchReq],
        timeout: Duration,
    ) -> Result<Vec<Vec<LogEntry>>>;

    fn commit_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()>;

    fn committed_part(&self, group: &str, topic: &str, partition: u32)
        -> Result<u64>;

    fn end_offset_of(&self, topic: &str, partition: u32) -> Result<u64>;

    /// Scrape the member's process-wide telemetry registry. `None` means
    /// the channel is in-process (an embedded [`BrokerState`]) — its
    /// metrics already live in the local registry, so there is nothing
    /// remote to fetch.
    fn scrape_telemetry(
        &self,
    ) -> Result<Option<crate::metrics::TelemetrySnapshot>> {
        Ok(None)
    }
}

impl PartitionBroker for BrokerState {
    fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> Result<u64> {
        Ok(BrokerState::produce_to(self, topic, partition, payload))
    }

    fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Result<Vec<u64>> {
        Ok(BrokerState::produce_many(self, topic, partition, payloads))
    }

    fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>> {
        Ok(BrokerState::fetch_from(self, topic, partition, offset, max, timeout))
    }

    fn fetch_many(
        &self,
        reqs: &[FetchReq],
        timeout: Duration,
    ) -> Result<Vec<Vec<LogEntry>>> {
        Ok(BrokerState::fetch_many(self, reqs, timeout))
    }

    fn commit_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()> {
        BrokerState::commit_part(self, group, topic, partition, offset);
        Ok(())
    }

    fn committed_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
    ) -> Result<u64> {
        Ok(BrokerState::committed_part(self, group, topic, partition))
    }

    fn end_offset_of(&self, topic: &str, partition: u32) -> Result<u64> {
        Ok(BrokerState::end_offset_of(self, topic, partition))
    }
}

impl PartitionBroker for BrokerClient {
    fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> Result<u64> {
        BrokerClient::produce_to(self, topic, partition, payload)
    }

    fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Result<Vec<u64>> {
        BrokerClient::produce_many(self, topic, partition, payloads)
    }

    fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>> {
        BrokerClient::fetch_from(self, topic, partition, offset, max, timeout)
    }

    fn fetch_many(
        &self,
        reqs: &[FetchReq],
        timeout: Duration,
    ) -> Result<Vec<Vec<LogEntry>>> {
        BrokerClient::fetch_many(self, reqs, timeout)
    }

    fn commit_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()> {
        BrokerClient::commit_part(self, group, topic, partition, offset)
    }

    fn committed_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
    ) -> Result<u64> {
        BrokerClient::committed_part(self, group, topic, partition)
    }

    fn end_offset_of(&self, topic: &str, partition: u32) -> Result<u64> {
        BrokerClient::end_offset_of(self, topic, partition)
    }

    fn scrape_telemetry(
        &self,
    ) -> Result<Option<crate::metrics::TelemetrySnapshot>> {
        BrokerClient::telemetry(self).map(Some)
    }
}

/// A broker instance behind a simulated link: every frame pays the link
/// latency, payload bytes pay wire time. Benches and the CLI demo use it
/// so the per-instance bottleneck the fabric removes is physically
/// present (mirrors `ThrottledConnector` on the store side).
pub struct ThrottledBroker {
    inner: Arc<dyn PartitionBroker>,
    link: Link,
}

impl ThrottledBroker {
    pub fn wrap(
        inner: Arc<dyn PartitionBroker>,
        latency: Duration,
        bandwidth: f64,
    ) -> Arc<ThrottledBroker> {
        Arc::new(ThrottledBroker {
            inner,
            link: Link::new(latency, bandwidth),
        })
    }
}

impl PartitionBroker for ThrottledBroker {
    fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> Result<u64> {
        self.link.transfer(payload.0.len());
        self.inner.produce_to(topic, partition, payload)
    }

    fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Result<Vec<u64>> {
        // Pipelined: one latency for the batch, wire time for the bytes.
        let total: usize = payloads.iter().map(|p| p.0.len()).sum();
        self.link.transfer(total);
        self.inner.produce_many(topic, partition, payloads)
    }

    fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Result<Vec<LogEntry>> {
        let out = self.inner.fetch_from(topic, partition, offset, max, timeout)?;
        let total: usize = out.iter().map(|e| e.payload.0.len()).sum();
        self.link.transfer(total);
        Ok(out)
    }

    fn fetch_many(
        &self,
        reqs: &[FetchReq],
        timeout: Duration,
    ) -> Result<Vec<Vec<LogEntry>>> {
        let out = self.inner.fetch_many(reqs, timeout)?;
        let total: usize = out
            .iter()
            .flat_map(|b| b.iter().map(|e| e.payload.0.len()))
            .sum();
        self.link.transfer(total);
        Ok(out)
    }

    fn commit_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()> {
        self.link.transfer(0);
        self.inner.commit_part(group, topic, partition, offset)
    }

    fn committed_part(
        &self,
        group: &str,
        topic: &str,
        partition: u32,
    ) -> Result<u64> {
        self.link.transfer(0);
        self.inner.committed_part(group, topic, partition)
    }

    fn end_offset_of(&self, topic: &str, partition: u32) -> Result<u64> {
        self.link.transfer(0);
        self.inner.end_offset_of(topic, partition)
    }

    fn scrape_telemetry(
        &self,
    ) -> Result<Option<crate::metrics::TelemetrySnapshot>> {
        // Observability traffic doesn't pay the simulated link: scrapes
        // model an out-of-band admin plane.
        self.inner.scrape_telemetry()
    }
}

// --------------------------------------------------------------------------
// BrokerFabric: instance placement
// --------------------------------------------------------------------------

/// Placement layer: N broker instances + a consistent-hash ring mapping
/// each `(topic, partition)` to one instance. Deterministic: any process
/// that builds a fabric from the same instance list routes identically,
/// which is what lets independent producers and consumers agree on where
/// a partition lives without coordination.
#[derive(Clone)]
pub struct BrokerFabric {
    instances: Vec<Arc<dyn PartitionBroker>>,
    ring: HashRing,
    partitions: u32,
    /// Per-topic partition→instance table, memoized on first use so the
    /// per-event hot paths (produce, publish, commit) index instead of
    /// re-hashing the ring; shared across clones.
    placements: Arc<Mutex<HashMap<String, Arc<Vec<usize>>>>>,
}

impl BrokerFabric {
    /// Fabric over explicit instances with `partitions` partitions per
    /// topic.
    pub fn new(
        instances: Vec<Arc<dyn PartitionBroker>>,
        partitions: u32,
    ) -> Result<BrokerFabric> {
        if instances.is_empty() {
            return Err(Error::Config("broker fabric needs >= 1 instance".into()));
        }
        if partitions == 0 {
            return Err(Error::Config("broker fabric needs >= 1 partition".into()));
        }
        Ok(BrokerFabric {
            ring: HashRing::new(instances.len(), crate::shard::DEFAULT_VNODES),
            instances,
            partitions,
            placements: Arc::new(Mutex::new(HashMap::new())),
        })
    }

    /// Convenience: fabric over `n` fresh embedded broker engines (the
    /// states are returned for gauge access / server frontends).
    pub fn embedded(n: usize, partitions: u32) -> Result<(BrokerFabric, Vec<BrokerState>)> {
        let states: Vec<BrokerState> =
            (0..n).map(|_| BrokerState::new()).collect();
        let fabric = BrokerFabric::new(
            states
                .iter()
                .map(|s| Arc::new(s.clone()) as Arc<dyn PartitionBroker>)
                .collect(),
            partitions,
        )?;
        Ok((fabric, states))
    }

    /// Fabric over TCP broker servers.
    pub fn connect(addrs: &[SocketAddr], partitions: u32) -> Result<BrokerFabric> {
        let instances = addrs
            .iter()
            .map(|&a| {
                Ok(Arc::new(BrokerClient::connect(a)?) as Arc<dyn PartitionBroker>)
            })
            .collect::<Result<Vec<_>>>()?;
        BrokerFabric::new(instances, partitions)
    }

    pub fn partitions(&self) -> u32 {
        self.partitions
    }

    pub fn instance_count(&self) -> usize {
        self.instances.len()
    }

    /// The full partition→instance table for a topic (computed once per
    /// topic, then served from the memo).
    fn placement(&self, topic: &str) -> Arc<Vec<usize>> {
        let mut memo = self.placements.lock().unwrap();
        if let Some(p) = memo.get(topic) {
            return p.clone();
        }
        let table: Arc<Vec<usize>> = Arc::new(
            (0..self.partitions)
                .map(|p| self.ring.shard_for(&format!("{topic}/p{p}")))
                .collect(),
        );
        memo.insert(topic.to_string(), table.clone());
        table
    }

    /// The instance hosting `(topic, partition)`.
    pub fn instance_for(&self, topic: &str, partition: u32) -> usize {
        self.placement(topic)[partition as usize]
    }

    pub fn instance(&self, idx: usize) -> &Arc<dyn PartitionBroker> {
        &self.instances[idx]
    }

    /// Partition for a routing key: same key, same partition, same order.
    pub fn partition_for_key(&self, key: &str) -> u32 {
        (hash_key(key.as_bytes()) % u64::from(self.partitions)) as u32
    }

    /// End-of-log offsets for every partition of a topic.
    pub fn end_offsets(&self, topic: &str) -> Result<Vec<u64>> {
        let placement = self.placement(topic);
        (0..self.partitions)
            .map(|p| {
                self.instances[placement[p as usize]].end_offset_of(topic, p)
            })
            .collect()
    }

    /// Append the same payload to *every* partition of a topic (control
    /// events such as end-of-stream markers that each partition's
    /// consumers must observe). Shared by [`PartitionedProducer`] and the
    /// stream publisher shim so broadcast semantics cannot diverge.
    pub fn broadcast(&self, topic: &str, payload: Bytes) -> Result<Vec<(u32, u64)>> {
        let placement = self.placement(topic);
        (0..self.partitions)
            .map(|p| {
                let off = self.instances[placement[p as usize]].produce_to(
                    topic,
                    p,
                    payload.clone(),
                )?;
                Ok((p, off))
            })
            .collect()
    }
}

// --------------------------------------------------------------------------
// Producer
// --------------------------------------------------------------------------

/// Partition selection policy for events without an explicit key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// Spread unkeyed events across partitions (maximum parallelism, no
    /// cross-event ordering).
    RoundRobin,
    /// Route by key hash (per-key total order); unkeyed events fall back
    /// to round-robin.
    ByKey,
}

/// Producer half of the fabric: routes each event to a partition and the
/// partition to its instance. Per-partition ordering is preserved because
/// one partition lives on exactly one instance and appends there are
/// serialized.
pub struct PartitionedProducer {
    fabric: BrokerFabric,
    partitioner: Partitioner,
    /// Per-topic round-robin cursor.
    cursors: HashMap<String, u32>,
    /// Cached per-partition telemetry handles (`broker.partition.{p}.produce`)
    /// — one registry lookup per partition per producer, not per event.
    part_counters: HashMap<u32, Arc<telemetry::Counter>>,
}

impl PartitionedProducer {
    pub fn new(fabric: BrokerFabric, partitioner: Partitioner) -> PartitionedProducer {
        PartitionedProducer {
            fabric,
            partitioner,
            cursors: HashMap::new(),
            part_counters: HashMap::new(),
        }
    }

    /// Account `n` appended events against `partition`.
    fn bump_produce(&mut self, partition: u32, n: u64) {
        broker_metrics().produce_events.add(n);
        self.part_counters
            .entry(partition)
            .or_insert_with(|| {
                telemetry::counter(&format!("broker.partition.{partition}.produce"))
            })
            .add(n);
    }

    pub fn fabric(&self) -> &BrokerFabric {
        &self.fabric
    }

    /// Partition the next event for `topic` lands on.
    fn partition_for(&mut self, topic: &str, key: Option<&str>) -> u32 {
        match (self.partitioner, key) {
            (Partitioner::ByKey, Some(k)) => self.fabric.partition_for_key(k),
            _ => {
                let n = self.fabric.partitions();
                let cursor = self.cursors.entry(topic.to_string()).or_insert(0);
                let p = *cursor % n;
                *cursor = cursor.wrapping_add(1);
                p
            }
        }
    }

    /// Append one event; returns its `(partition, offset)` position.
    pub fn produce(
        &mut self,
        topic: &str,
        key: Option<&str>,
        payload: Bytes,
    ) -> Result<(u32, u64)> {
        let partition = self.partition_for(topic, key);
        let inst = self.fabric.instance_for(topic, partition);
        let offset =
            self.fabric.instances[inst].produce_to(topic, partition, payload)?;
        self.bump_produce(partition, 1);
        Ok((partition, offset))
    }

    /// Append a batch: events are partitioned, grouped, and appended with
    /// one `ProduceMany` per partition, all instances in parallel. Returns
    /// `(partition, offset)` per event, aligned with the input; events
    /// that share a partition keep their input order.
    ///
    /// On error, sub-batches that reached healthy instances may already
    /// be durably appended (their placements are discarded with the
    /// error) — retrying the whole batch can duplicate those events, the
    /// standard at-least-once contract of a non-idempotent producer.
    pub fn produce_many(
        &mut self,
        topic: &str,
        events: Vec<(Option<String>, Bytes)>,
    ) -> Result<Vec<(u32, u64)>> {
        if events.is_empty() {
            return Ok(Vec::new());
        }
        // Partition assignment in input order (keeps round-robin stable).
        let mut groups: HashMap<u32, (Vec<usize>, Vec<Bytes>)> = HashMap::new();
        for (i, (key, payload)) in events.into_iter().enumerate() {
            let p = self.partition_for(topic, key.as_deref());
            let entry = groups.entry(p).or_default();
            entry.0.push(i);
            entry.1.push(payload);
        }
        let jobs: Vec<((Vec<usize>, u32), Job<Vec<u64>>)> = groups
            .into_iter()
            .map(|(partition, (idxs, payloads))| {
                let inst = self.fabric.instance_for(topic, partition);
                let broker = self.fabric.instances[inst].clone();
                let topic = topic.to_string();
                (
                    (idxs, partition),
                    Box::new(move || {
                        broker.produce_many(&topic, partition, payloads)
                    }) as Job<Vec<u64>>,
                )
            })
            .collect();
        // Shared reactor pool: every sub-batch in flight at once, no
        // per-call thread spawns.
        let results: ProduceResults = fan_out(jobs);
        let total: usize =
            results.iter().map(|((idxs, _), _)| idxs.len()).sum();
        let mut out = vec![(0u32, 0u64); total];
        for ((idxs, partition), res) in results {
            let offsets = res?;
            self.bump_produce(partition, idxs.len() as u64);
            for (&i, off) in idxs.iter().zip(offsets) {
                out[i] = (partition, off);
            }
        }
        Ok(out)
    }

    /// Append the same payload to *every* partition (see
    /// [`BrokerFabric::broadcast`]).
    pub fn broadcast(&self, topic: &str, payload: Bytes) -> Result<Vec<(u32, u64)>> {
        self.fabric.broadcast(topic, payload)
    }
}

// --------------------------------------------------------------------------
// Consumer
// --------------------------------------------------------------------------

/// Deterministic partition assignment for a consumer group: member `m` of
/// `members` owns every partition `p` with `p % members == m`. Every
/// partition is owned by exactly one member, and a join/leave (different
/// `members`) rebalances deterministically on all members at once.
pub fn assign_partitions(partitions: u32, members: usize, member: usize) -> Vec<u32> {
    let members = members.max(1) as u32;
    let member = member as u32 % members;
    (0..partitions).filter(|p| p % members == member).collect()
}

/// Consumer half of the fabric: fan-in fetch over the member's assigned
/// partitions with per-partition offsets (and optional consumer-group
/// commits). Entries from one partition arrive in partition order;
/// cross-partition interleaving is unspecified, as in Kafka.
pub struct PartitionedConsumer {
    fabric: BrokerFabric,
    topic: String,
    group: Option<String>,
    assigned: Vec<u32>,
    offsets: HashMap<u32, u64>,
    /// Assigned partitions grouped by hosting instance — placement is
    /// fixed at construction, so each sweep only patches offsets instead
    /// of re-hashing the ring per partition per round.
    grouping: Vec<(usize, Vec<u32>)>,
    /// Max entries per partition per fetch round.
    fetch_max: u32,
    /// Entries fetched but not yet handed out by [`PartitionedConsumer::next`].
    buffer: VecDeque<(u32, LogEntry)>,
    /// Fetch rounds that hit at least one instance error (diagnostics).
    instance_errors: AtomicU64,
}

/// Group a member's partitions by the instance hosting them.
fn group_by_instance(
    fabric: &BrokerFabric,
    topic: &str,
    assigned: &[u32],
) -> Vec<(usize, Vec<u32>)> {
    let mut groups: HashMap<usize, Vec<u32>> = HashMap::new();
    for &p in assigned {
        groups.entry(fabric.instance_for(topic, p)).or_default().push(p);
    }
    let mut v: Vec<(usize, Vec<u32>)> = groups.into_iter().collect();
    v.sort_unstable_by_key(|(inst, _)| *inst);
    v
}

impl PartitionedConsumer {
    /// Member `member` of a `members`-strong anonymous group, starting at
    /// offset 0 on its assigned partitions.
    pub fn new(
        fabric: BrokerFabric,
        topic: &str,
        member: usize,
        members: usize,
    ) -> Result<PartitionedConsumer> {
        let assigned = assign_partitions(fabric.partitions(), members, member);
        let offsets = assigned.iter().map(|&p| (p, 0)).collect();
        let grouping = group_by_instance(&fabric, topic, &assigned);
        Ok(PartitionedConsumer {
            fabric,
            topic: topic.to_string(),
            group: None,
            assigned,
            offsets,
            grouping,
            fetch_max: 64,
            buffer: VecDeque::new(),
            instance_errors: AtomicU64::new(0),
        })
    }

    /// Group member resuming from the group's committed offsets; `commit`
    /// persists progress per partition.
    pub fn with_group(
        fabric: BrokerFabric,
        topic: &str,
        group: &str,
        member: usize,
        members: usize,
    ) -> Result<PartitionedConsumer> {
        let assigned = assign_partitions(fabric.partitions(), members, member);
        let mut offsets = HashMap::with_capacity(assigned.len());
        for &p in &assigned {
            let inst = fabric.instance_for(topic, p);
            offsets.insert(p, fabric.instances[inst].committed_part(group, topic, p)?);
        }
        let grouping = group_by_instance(&fabric, topic, &assigned);
        Ok(PartitionedConsumer {
            fabric,
            topic: topic.to_string(),
            group: Some(group.to_string()),
            assigned,
            offsets,
            grouping,
            fetch_max: 64,
            buffer: VecDeque::new(),
            instance_errors: AtomicU64::new(0),
        })
    }

    /// Cap entries per partition per fetch round.
    pub fn set_fetch_max(&mut self, max: u32) {
        self.fetch_max = max.max(1);
    }

    /// This member's partitions.
    pub fn assigned(&self) -> &[u32] {
        &self.assigned
    }

    /// Next offset to consume per partition.
    pub fn positions(&self) -> &HashMap<u32, u64> {
        &self.offsets
    }

    /// Fetch rounds that saw at least one unreachable instance.
    pub fn instance_errors(&self) -> u64 {
        self.instance_errors.load(Ordering::Relaxed)
    }

    /// One fan-out round over every instance hosting our partitions, each
    /// instance's partitions batched into a single `FetchMany`, all
    /// instances in parallel. Returns whatever was available within
    /// `timeout`. If some instances fail but any data arrived, the data is
    /// returned (and the error round counted); an all-error round
    /// surfaces the failure.
    fn sweep(&self, timeout: Duration) -> Result<Vec<(u32, LogEntry)>> {
        // Placement was grouped once at construction; only the offsets
        // change between rounds.
        let per_inst: Vec<(usize, Vec<FetchReq>)> = self
            .grouping
            .iter()
            .map(|(inst, parts)| {
                let reqs = parts
                    .iter()
                    .map(|&p| {
                        (self.topic.clone(), p, self.offsets[&p], self.fetch_max)
                    })
                    .collect();
                (*inst, reqs)
            })
            .collect();
        // Deliberately NOT on the shared reactor pool: a fetch sweep is a
        // long-poll that parks inside `fetch_many` for up to the full
        // sweep slice, and the pool's contract is short-lived jobs only —
        // parked fetches would starve shard fan-outs and migration
        // batches process-wide. Scoped threads keep idle consumers
        // decoupled from the data plane.
        let results: SweepResults = std::thread::scope(|s| {
            let handles: Vec<_> = per_inst
                .into_iter()
                .map(|(inst, reqs)| {
                    let broker = self.fabric.instances[inst].clone();
                    s.spawn(move || {
                        let res = broker.fetch_many(&reqs, timeout);
                        (reqs, res)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        (
                            Vec::new(),
                            Err(Error::Connector(
                                "broker fetch_many panicked".into(),
                            )),
                        )
                    })
                })
                .collect()
        });
        let mut out: Vec<(u32, LogEntry)> = Vec::new();
        let mut last_err = None;
        for (reqs, res) in results {
            match res {
                Ok(batches) => {
                    for ((_, partition, _, _), batch) in
                        reqs.into_iter().zip(batches)
                    {
                        for entry in batch {
                            out.push((partition, entry));
                        }
                    }
                }
                Err(e) => last_err = Some(e),
            }
        }
        if let Some(e) = last_err {
            self.instance_errors.fetch_add(1, Ordering::Relaxed);
            if out.is_empty() {
                return Err(e);
            }
        }
        // Deterministic merge order within a round.
        out.sort_by_key(|(p, e)| (*p, e.offset));
        broker_metrics().fetch_events.add(out.len() as u64);
        Ok(out)
    }

    fn advance(&mut self, entries: &[(u32, LogEntry)]) {
        for (p, e) in entries {
            let pos = self.offsets.entry(*p).or_insert(0);
            *pos = (*pos).max(e.offset + 1);
        }
    }

    /// Fetch the next batch across all assigned partitions, waiting up to
    /// `timeout` for at least one entry. A fast zero-wait sweep serves
    /// already-available data immediately; only a fully drained
    /// assignment enters the blocking path, which long-polls in bounded
    /// slices so data arriving on one instance is never gated on another
    /// instance's idle timeout.
    pub fn poll(&mut self, timeout: Duration) -> Result<Vec<(u32, LogEntry)>> {
        let mut got = self.sweep(Duration::ZERO)?;
        if got.is_empty() && !timeout.is_zero() {
            let deadline = Instant::now() + timeout;
            // Slicing exists so one instance's idle long poll cannot gate
            // data arriving on another — data always returns immediately
            // via the broker's wake-up; only idle waits pay the slice.
            // Empty rounds widen the slice exponentially, so a freshly
            // active consumer reacts within 20 ms while a long-idle one
            // costs ~4 sweep rounds/second instead of 50. The cap also
            // bounds how long one sweep holds a shared TCP client pipe.
            const SLICE: Duration = Duration::from_millis(20);
            const MAX_SLICE: Duration = Duration::from_millis(250);
            let mut slice = SLICE;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                got = self.sweep(slice.min(deadline - now))?;
                if !got.is_empty() {
                    break;
                }
                slice = (slice * 2).min(MAX_SLICE);
            }
        }
        self.advance(&got);
        Ok(got)
    }

    /// Next single entry (buffered poll); `Ok(None)` on timeout.
    pub fn next(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<(u32, LogEntry)>> {
        if self.buffer.is_empty() {
            let got = self.poll(timeout)?;
            self.buffer.extend(got);
        }
        Ok(self.buffer.pop_front())
    }

    /// Commit one partition's offset for an explicit group (fine-grained
    /// per-delivery commits; the stream shim uses this so a crash replays
    /// at most the in-flight event, not a whole fetch batch).
    pub fn commit_position(
        &self,
        group: &str,
        partition: u32,
        offset: u64,
    ) -> Result<()> {
        let inst = self.fabric.instance_for(&self.topic, partition);
        self.fabric.instances[inst].commit_part(group, &self.topic, partition, offset)
    }

    /// Commit this member's positions for its consumer group, one commit
    /// per partition on the partition's own instance.
    pub fn commit(&self) -> Result<()> {
        let Some(group) = &self.group else {
            return Err(Error::Config(
                "commit on a consumer without a group".into(),
            ));
        };
        for &p in &self.assigned {
            let inst = self.fabric.instance_for(&self.topic, p);
            self.fabric.instances[inst].commit_part(
                group,
                &self.topic,
                p,
                self.offsets[&p],
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn embedded(n: usize, partitions: u32) -> (BrokerFabric, Vec<BrokerState>) {
        BrokerFabric::embedded(n, partitions).unwrap()
    }

    #[test]
    fn assignment_partitions_exactly_once() {
        for partitions in [1u32, 3, 8, 17] {
            for members in [1usize, 2, 3, 5, 8] {
                let mut owners = vec![0usize; partitions as usize];
                for m in 0..members {
                    for p in assign_partitions(partitions, members, m) {
                        owners[p as usize] += 1;
                    }
                }
                assert!(
                    owners.iter().all(|&c| c == 1),
                    "p={partitions} m={members}: owners {owners:?}"
                );
            }
        }
        // More members than partitions: the surplus members idle.
        assert!(assign_partitions(2, 5, 4).is_empty());
        assert_eq!(assign_partitions(2, 5, 0), vec![0]);
    }

    #[test]
    fn assignment_rebalances_on_membership_change() {
        // A join (members 2 -> 3) recomputes a complete, disjoint
        // assignment; ditto a leave (3 -> 2). Deterministic on every
        // member, no coordinator required.
        for members in [2usize, 3] {
            let all: Vec<Vec<u32>> = (0..members)
                .map(|m| assign_partitions(12, members, m))
                .collect();
            let mut seen: Vec<u32> = all.concat();
            seen.sort_unstable();
            assert_eq!(seen, (0..12).collect::<Vec<_>>());
        }
    }

    #[test]
    fn placement_is_deterministic_across_fabrics() {
        let (a, _) = embedded(4, 16);
        let (b, _) = embedded(4, 16);
        for p in 0..16 {
            assert_eq!(a.instance_for("t", p), b.instance_for("t", p));
        }
        // Partitions actually spread over instances.
        let mut hit = vec![false; 4];
        for p in 0..16 {
            hit[a.instance_for("t", p)] = true;
        }
        assert!(hit.iter().filter(|&&h| h).count() >= 2, "no spread: {hit:?}");
    }

    #[test]
    fn fabric_validation() {
        assert!(BrokerFabric::new(Vec::new(), 4).is_err());
        let state = BrokerState::new();
        assert!(BrokerFabric::new(
            vec![Arc::new(state) as Arc<dyn PartitionBroker>],
            0
        )
        .is_err());
    }

    #[test]
    fn by_key_partitioner_pins_keys() {
        let (fabric, _) = embedded(3, 8);
        let mut prod = PartitionedProducer::new(fabric, Partitioner::ByKey);
        let (p1, o1) = prod.produce("t", Some("alice"), Bytes(vec![1])).unwrap();
        let (p2, o2) = prod.produce("t", Some("alice"), Bytes(vec![2])).unwrap();
        assert_eq!(p1, p2, "same key must stay on one partition");
        assert_eq!((o1, o2), (0, 1), "per-key ordering is the offset order");
        // Unkeyed events fall back to round-robin over all partitions.
        let mut parts: Vec<u32> = (0..8)
            .map(|i| prod.produce("t", None, Bytes(vec![i])).unwrap().0)
            .collect();
        parts.sort_unstable();
        parts.dedup();
        assert_eq!(parts.len(), 8);
    }

    #[test]
    fn round_robin_spreads_and_produce_many_aligns() {
        let (fabric, states) = embedded(4, 4);
        let mut prod =
            PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
        let events: Vec<(Option<String>, Bytes)> =
            (0..16u8).map(|i| (None, Bytes(vec![i]))).collect();
        let placed = prod.produce_many("t", events).unwrap();
        assert_eq!(placed.len(), 16);
        // Round-robin: event i lands on partition i % 4 at offset i / 4.
        for (i, &(p, o)) in placed.iter().enumerate() {
            assert_eq!(p, (i % 4) as u32);
            assert_eq!(o, (i / 4) as u64);
        }
        // Entries are really on the placed instance, in input order.
        for p in 0..4u32 {
            let inst = fabric.instance_for("t", p);
            let log = states[inst].fetch_from("t", p, 0, 64, Duration::ZERO);
            let vals: Vec<u8> = log.iter().map(|e| e.payload.0[0]).collect();
            let expect: Vec<u8> =
                (0..16u8).filter(|i| u32::from(*i) % 4 == p).collect();
            assert_eq!(vals, expect, "partition {p} out of order");
        }
        assert_eq!(fabric.end_offsets("t").unwrap(), vec![4, 4, 4, 4]);
        assert!(prod.produce_many("t", Vec::new()).unwrap().is_empty());
    }

    #[test]
    fn consumer_fans_in_all_partitions_in_order() {
        let (fabric, _) = embedded(3, 6);
        let mut prod =
            PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
        for i in 0..30u8 {
            prod.produce("t", None, Bytes(vec![i])).unwrap();
        }
        let mut consumer =
            PartitionedConsumer::new(fabric, "t", 0, 1).unwrap();
        assert_eq!(consumer.assigned().len(), 6);
        let mut per_part: HashMap<u32, Vec<u8>> = HashMap::new();
        let mut total = 0;
        while total < 30 {
            let got = consumer.poll(Duration::from_secs(2)).unwrap();
            assert!(!got.is_empty(), "poll starved at {total}/30");
            for (p, e) in got {
                per_part.entry(p).or_default().push(e.payload.0[0]);
                total += 1;
            }
        }
        // Per-partition order == production order on that partition.
        for (p, vals) in per_part {
            let expect: Vec<u8> =
                (0..30u8).filter(|i| u32::from(*i) % 6 == p).collect();
            assert_eq!(vals, expect, "partition {p} misordered");
        }
        // Drained: a zero-wait poll returns nothing.
        assert!(consumer.poll(Duration::ZERO).unwrap().is_empty());
    }

    #[test]
    fn poll_wakes_on_late_produce() {
        let (fabric, _) = embedded(2, 4);
        let mut consumer =
            PartitionedConsumer::new(fabric.clone(), "t", 0, 1).unwrap();
        let h = std::thread::spawn(move || {
            consumer.poll(Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let mut prod = PartitionedProducer::new(fabric, Partitioner::RoundRobin);
        prod.produce("t", None, Bytes(vec![9])).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].1.payload, Bytes(vec![9]));
    }

    #[test]
    fn group_members_split_the_stream_and_resume_from_commits() {
        let (fabric, _) = embedded(2, 4);
        let mut prod =
            PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
        for i in 0..20u8 {
            prod.produce("t", None, Bytes(vec![i])).unwrap();
        }
        // Two members: disjoint partitions, union = everything.
        let mut seen = Vec::new();
        for m in 0..2 {
            let mut c = PartitionedConsumer::with_group(
                fabric.clone(),
                "t",
                "g",
                m,
                2,
            )
            .unwrap();
            loop {
                let got = c.poll(Duration::ZERO).unwrap();
                if got.is_empty() {
                    break;
                }
                seen.extend(got.iter().map(|(_, e)| e.payload.0[0]));
            }
            c.commit().unwrap();
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..20u8).collect::<Vec<_>>());

        // A fresh member with the same group resumes past everything.
        let mut resumed = PartitionedConsumer::with_group(
            fabric.clone(),
            "t",
            "g",
            0,
            2,
        )
        .unwrap();
        assert!(resumed.poll(Duration::ZERO).unwrap().is_empty());
        // A different group starts from scratch.
        let mut fresh =
            PartitionedConsumer::with_group(fabric, "t", "g2", 0, 1).unwrap();
        assert_eq!(fresh.poll(Duration::ZERO).unwrap().len(), 20);
    }

    #[test]
    fn next_buffers_and_commit_requires_group() {
        let (fabric, _) = embedded(2, 2);
        let mut prod =
            PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
        for i in 0..4u8 {
            prod.produce("t", None, Bytes(vec![i])).unwrap();
        }
        let mut c = PartitionedConsumer::new(fabric, "t", 0, 1).unwrap();
        let mut n = 0;
        while let Some((_, _e)) = c.next(Duration::ZERO).unwrap() {
            n += 1;
        }
        assert_eq!(n, 4);
        assert!(matches!(c.commit(), Err(Error::Config(_))));
    }

    #[test]
    fn broadcast_reaches_every_partition() {
        let (fabric, _) = embedded(3, 5);
        let prod =
            PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
        let placed = prod.broadcast("t", Bytes(vec![42])).unwrap();
        assert_eq!(placed.len(), 5);
        assert_eq!(fabric.end_offsets("t").unwrap(), vec![1; 5]);
    }
}
