//! Broker storage engine: append-only partitioned topic logs plus
//! consumer-group offsets.
//!
//! A topic is a set of numbered partitions, each an independent
//! append-only log with its own dense offset space. The classic
//! single-log API (`produce`/`fetch`/...) operates on partition 0, so
//! unpartitioned callers are just the one-partition special case.
//! Commits are tracked per `(group, topic, partition)`.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Bytes;
use crate::metrics::StoreBytes;

/// One log entry (offset is partition-local and dense from 0).
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    pub offset: u64,
    pub payload: Bytes,
}

/// A fetch request against one partition: `(topic, partition, offset,
/// max)`. [`BrokerState::fetch_many`] serves a whole slice of these in one
/// lock acquisition (and one wire frame over TCP).
pub type FetchReq = (String, u32, u64, u32);

#[derive(Default)]
struct Inner {
    /// topic -> partition -> log. Nested (rather than a `(String, u32)`
    /// key) so the fetch hot path — re-probed on every long-poll wake —
    /// looks up by `&str` without allocating a key.
    topics: HashMap<String, HashMap<u32, Vec<LogEntry>>>,
    /// (group, topic, partition) -> committed offset.
    commits: HashMap<(String, String, u32), u64>,
}

impl Inner {
    fn log(&self, topic: &str, partition: u32) -> Option<&Vec<LogEntry>> {
        self.topics.get(topic).and_then(|parts| parts.get(&partition))
    }

    fn slice(&self, topic: &str, partition: u32, offset: u64, max: u32) -> Vec<LogEntry> {
        match self.log(topic, partition) {
            Some(log) if (log.len() as u64) > offset => {
                let start = offset as usize;
                let end = (start + max as usize).min(log.len());
                log[start..end].to_vec()
            }
            _ => Vec::new(),
        }
    }
}

/// Embedded broker engine; cheap to clone.
#[derive(Clone)]
pub struct BrokerState {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    /// Bytes resident across all topic logs (event metadata is small, but
    /// the Fig 6 "data through the broker" baseline pushes bulk here).
    pub gauge: Arc<StoreBytes>,
}

impl Default for BrokerState {
    fn default() -> Self {
        Self::new()
    }
}

impl BrokerState {
    pub fn new() -> Self {
        BrokerState {
            inner: Arc::new((Mutex::new(Inner::default()), Condvar::new())),
            gauge: StoreBytes::new(),
        }
    }

    /// Append to partition 0; returns the assigned offset.
    pub fn produce(&self, topic: &str, payload: Bytes) -> u64 {
        self.produce_to(topic, 0, payload)
    }

    /// Append to a specific partition; returns the assigned offset.
    pub fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> u64 {
        let (m, cv) = &*self.inner;
        let mut inner = m.lock().unwrap();
        self.gauge.add(payload.0.len());
        let log = inner
            .topics
            .entry(topic.to_string())
            .or_default()
            .entry(partition)
            .or_default();
        let offset = log.len() as u64;
        log.push(LogEntry { offset, payload });
        cv.notify_all();
        offset
    }

    /// Append a batch to one partition under a single lock acquisition and
    /// a single waiter wake-up; returns the assigned offsets (dense).
    pub fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Vec<u64> {
        if payloads.is_empty() {
            return Vec::new();
        }
        let (m, cv) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let log = inner
            .topics
            .entry(topic.to_string())
            .or_default()
            .entry(partition)
            .or_default();
        let mut offsets = Vec::with_capacity(payloads.len());
        let mut bytes = 0usize;
        for payload in payloads {
            bytes += payload.0.len();
            let offset = log.len() as u64;
            log.push(LogEntry { offset, payload });
            offsets.push(offset);
        }
        self.gauge.add(bytes);
        cv.notify_all();
        offsets
    }

    /// Fetch up to `max` entries from partition 0 (see
    /// [`BrokerState::fetch_from`]).
    pub fn fetch(
        &self,
        topic: &str,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Vec<LogEntry> {
        self.fetch_from(topic, 0, offset, max, timeout)
    }

    /// Fetch up to `max` entries of a partition from `offset`, long-polling
    /// up to `timeout` for at least one entry (`Duration::ZERO` = no wait).
    pub fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Vec<LogEntry> {
        if max == 0 {
            // A zero-entry request can never be satisfied; don't park the
            // caller on the long poll.
            return Vec::new();
        }
        let (m, cv) = &*self.inner;
        let deadline = Instant::now() + timeout;
        let mut inner = m.lock().unwrap();
        loop {
            let entries = inner.slice(topic, partition, offset, max);
            if !entries.is_empty() {
                return entries;
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _) = cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Multi-partition fetch: serve every request in `reqs`, long-polling
    /// up to `timeout` until at least one request has data. Results align
    /// positionally with `reqs`. This is the fan-in primitive a
    /// partitioned consumer polls its whole assignment with — one lock
    /// acquisition (one frame over TCP) instead of one per partition.
    pub fn fetch_many(&self, reqs: &[FetchReq], timeout: Duration) -> Vec<Vec<LogEntry>> {
        if reqs.is_empty() {
            return Vec::new();
        }
        // All-zero `max` can never produce an entry; answer immediately
        // instead of long-polling (zero-max members of a mixed batch are
        // simply never the wake-up reason).
        if reqs.iter().all(|(_, _, _, max)| *max == 0) {
            return vec![Vec::new(); reqs.len()];
        }
        let (m, cv) = &*self.inner;
        let deadline = Instant::now() + timeout;
        let mut inner = m.lock().unwrap();
        loop {
            let out: Vec<Vec<LogEntry>> = reqs
                .iter()
                .map(|(topic, part, offset, max)| {
                    inner.slice(topic, *part, *offset, *max)
                })
                .collect();
            if out.iter().any(|e| !e.is_empty()) {
                return out;
            }
            let now = Instant::now();
            if now >= deadline {
                return out;
            }
            let (guard, _) = cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    pub fn end_offset(&self, topic: &str) -> u64 {
        self.end_offset_of(topic, 0)
    }

    pub fn end_offset_of(&self, topic: &str, partition: u32) -> u64 {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        inner
            .log(topic, partition)
            .map(|log| log.len() as u64)
            .unwrap_or(0)
    }

    pub fn commit(&self, group: &str, topic: &str, offset: u64) {
        self.commit_part(group, topic, 0, offset);
    }

    pub fn commit_part(&self, group: &str, topic: &str, partition: u32, offset: u64) {
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        inner
            .commits
            .insert((group.to_string(), topic.to_string(), partition), offset);
    }

    pub fn committed(&self, group: &str, topic: &str) -> u64 {
        self.committed_part(group, topic, 0)
    }

    pub fn committed_part(&self, group: &str, topic: &str, partition: u32) -> u64 {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        inner
            .commits
            .get(&(group.to_string(), topic.to_string(), partition))
            .copied()
            .unwrap_or(0)
    }

    pub fn topics(&self) -> Vec<String> {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        let mut v: Vec<String> = inner.topics.keys().cloned().collect();
        v.sort();
        v
    }

    /// Partitions of a topic that hold at least one entry, ascending.
    pub fn partitions(&self, topic: &str) -> Vec<u32> {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        let mut v: Vec<u32> = inner
            .topics
            .get(topic)
            .map(|parts| parts.keys().copied().collect())
            .unwrap_or_default();
        v.sort_unstable();
        v
    }

    /// Truncate entries below `offset` on partition 0 (see
    /// [`BrokerState::truncate_part`]).
    pub fn truncate(&self, topic: &str, below: u64) -> usize {
        self.truncate_part(topic, 0, below)
    }

    /// Truncate entries below `offset` on a partition (retention),
    /// returning freed bytes. Offsets remain stable: the log keeps logical
    /// offsets.
    pub fn truncate_part(&self, topic: &str, partition: u32, below: u64) -> usize {
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let Some(log) = inner
            .topics
            .get_mut(topic)
            .and_then(|parts| parts.get_mut(&partition))
        else {
            return 0;
        };
        let mut freed = 0;
        // Replace truncated payloads with empty bytes, keeping offsets dense.
        for e in log.iter_mut().filter(|e| e.offset < below) {
            freed += e.payload.0.len();
            e.payload = Bytes(Vec::new());
        }
        self.gauge.sub(freed);
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_assigns_dense_offsets() {
        let b = BrokerState::new();
        assert_eq!(b.produce("t", Bytes(vec![1])), 0);
        assert_eq!(b.produce("t", Bytes(vec![2])), 1);
        assert_eq!(b.produce("u", Bytes(vec![3])), 0);
        assert_eq!(b.end_offset("t"), 2);
        assert_eq!(b.topics(), vec!["t".to_string(), "u".to_string()]);
    }

    #[test]
    fn partitions_are_independent_logs() {
        let b = BrokerState::new();
        assert_eq!(b.produce_to("t", 0, Bytes(vec![0])), 0);
        assert_eq!(b.produce_to("t", 1, Bytes(vec![1])), 0);
        assert_eq!(b.produce_to("t", 1, Bytes(vec![2])), 1);
        assert_eq!(b.end_offset_of("t", 0), 1);
        assert_eq!(b.end_offset_of("t", 1), 2);
        assert_eq!(b.end_offset_of("t", 7), 0);
        assert_eq!(b.partitions("t"), vec![0, 1]);
        // Topic list dedups across partitions.
        assert_eq!(b.topics(), vec!["t".to_string()]);
        let got = b.fetch_from("t", 1, 0, 10, Duration::ZERO);
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].payload, Bytes(vec![2]));
    }

    #[test]
    fn produce_many_is_dense_and_gauged() {
        let b = BrokerState::new();
        b.produce_to("t", 3, Bytes(vec![9; 10]));
        let offs = b.produce_many(
            "t",
            3,
            vec![Bytes(vec![0; 5]), Bytes(vec![1; 5]), Bytes(vec![2; 5])],
        );
        assert_eq!(offs, vec![1, 2, 3]);
        assert_eq!(b.gauge.get(), 25);
        assert!(b.produce_many("t", 3, Vec::new()).is_empty());
    }

    #[test]
    fn fetch_returns_in_order() {
        let b = BrokerState::new();
        for i in 0..5u8 {
            b.produce("t", Bytes(vec![i]));
        }
        let entries = b.fetch("t", 1, 2, Duration::ZERO);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].offset, 1);
        assert_eq!(entries[1].payload, Bytes(vec![2]));
        assert!(b.fetch("t", 5, 10, Duration::ZERO).is_empty());
    }

    #[test]
    fn fetch_long_poll_wakes_on_produce() {
        let b = BrokerState::new();
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            b2.fetch("t", 0, 10, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        b.produce("t", Bytes(vec![9]));
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].payload, Bytes(vec![9]));
    }

    #[test]
    fn fetch_many_aligns_and_wakes_on_any_partition() {
        let b = BrokerState::new();
        b.produce_to("t", 0, Bytes(vec![1]));
        let reqs: Vec<FetchReq> = vec![
            ("t".into(), 0, 0, 10),
            ("t".into(), 1, 0, 10),
            ("u".into(), 0, 0, 10),
        ];
        let got = b.fetch_many(&reqs, Duration::ZERO);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].len(), 1);
        assert!(got[1].is_empty() && got[2].is_empty());

        // Long poll returns as soon as any requested partition has data.
        let b2 = b.clone();
        let reqs2: Vec<FetchReq> =
            vec![("t".into(), 1, 0, 10), ("t".into(), 2, 0, 10)];
        let h = std::thread::spawn(move || {
            b2.fetch_many(&reqs2, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        b.produce_to("t", 2, Bytes(vec![7]));
        let got = h.join().unwrap();
        assert!(got[0].is_empty());
        assert_eq!(got[1].len(), 1);
        assert_eq!(got[1][0].payload, Bytes(vec![7]));

        // Empty request set returns immediately.
        assert!(b.fetch_many(&[], Duration::from_secs(5)).is_empty());
    }

    #[test]
    fn zero_max_fetch_returns_immediately() {
        let b = BrokerState::new();
        b.produce("t", Bytes(vec![1]));
        let t0 = Instant::now();
        assert!(b.fetch("t", 0, 0, Duration::from_secs(5)).is_empty());
        let reqs: Vec<FetchReq> =
            vec![("t".into(), 0, 0, 0), ("u".into(), 0, 0, 0)];
        let got = b.fetch_many(&reqs, Duration::from_secs(5));
        assert_eq!(got, vec![Vec::new(), Vec::new()]);
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "zero-max fetch must not long-poll"
        );
    }

    #[test]
    fn fetch_timeout_returns_empty() {
        let b = BrokerState::new();
        let t0 = Instant::now();
        let got = b.fetch("t", 0, 1, Duration::from_millis(25));
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn commits_per_group_and_partition() {
        let b = BrokerState::new();
        assert_eq!(b.committed("g1", "t"), 0);
        b.commit("g1", "t", 5);
        b.commit("g2", "t", 2);
        assert_eq!(b.committed("g1", "t"), 5);
        assert_eq!(b.committed("g2", "t"), 2);
        // Partitioned commits are independent of partition 0's.
        b.commit_part("g1", "t", 4, 9);
        assert_eq!(b.committed_part("g1", "t", 4), 9);
        assert_eq!(b.committed("g1", "t"), 5);
    }

    #[test]
    fn truncate_frees_bytes_keeps_offsets() {
        let b = BrokerState::new();
        for _ in 0..4 {
            b.produce("t", Bytes(vec![0; 100]));
        }
        assert_eq!(b.gauge.get(), 400);
        let freed = b.truncate("t", 2);
        assert_eq!(freed, 200);
        assert_eq!(b.gauge.get(), 200);
        // Offsets still line up after truncation.
        let entries = b.fetch("t", 2, 10, Duration::ZERO);
        assert_eq!(entries[0].offset, 2);
        assert_eq!(entries[0].payload.0.len(), 100);
    }
}
