//! Broker storage engine: append-only topic logs + consumer-group offsets.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Bytes;
use crate::metrics::StoreBytes;

/// One log entry (offset is topic-local and dense from 0).
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    pub offset: u64,
    pub payload: Bytes,
}

#[derive(Default)]
struct Inner {
    topics: HashMap<String, Vec<LogEntry>>,
    commits: HashMap<(String, String), u64>, // (group, topic) -> offset
}

/// Embedded broker engine; cheap to clone.
#[derive(Clone)]
pub struct BrokerState {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    /// Bytes resident across all topic logs (event metadata is small, but
    /// the Fig 6 "data through the broker" baseline pushes bulk here).
    pub gauge: Arc<StoreBytes>,
}

impl Default for BrokerState {
    fn default() -> Self {
        Self::new()
    }
}

impl BrokerState {
    pub fn new() -> Self {
        BrokerState {
            inner: Arc::new((Mutex::new(Inner::default()), Condvar::new())),
            gauge: StoreBytes::new(),
        }
    }

    /// Append; returns the assigned offset.
    pub fn produce(&self, topic: &str, payload: Bytes) -> u64 {
        let (m, cv) = &*self.inner;
        let mut inner = m.lock().unwrap();
        self.gauge.add(payload.0.len());
        let log = inner.topics.entry(topic.to_string()).or_default();
        let offset = log.len() as u64;
        log.push(LogEntry { offset, payload });
        cv.notify_all();
        offset
    }

    /// Fetch up to `max` entries from `offset`, long-polling up to
    /// `timeout` for at least one entry (`Duration::ZERO` = no wait).
    pub fn fetch(
        &self,
        topic: &str,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Vec<LogEntry> {
        let (m, cv) = &*self.inner;
        let deadline = Instant::now() + timeout;
        let mut inner = m.lock().unwrap();
        loop {
            let available = inner
                .topics
                .get(topic)
                .map(|log| log.len() as u64)
                .unwrap_or(0);
            if available > offset {
                let log = &inner.topics[topic];
                let start = offset as usize;
                let end = (offset as usize + max as usize).min(log.len());
                return log[start..end].to_vec();
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _) = cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    pub fn end_offset(&self, topic: &str) -> u64 {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        inner
            .topics
            .get(topic)
            .map(|log| log.len() as u64)
            .unwrap_or(0)
    }

    pub fn commit(&self, group: &str, topic: &str, offset: u64) {
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        inner
            .commits
            .insert((group.to_string(), topic.to_string()), offset);
    }

    pub fn committed(&self, group: &str, topic: &str) -> u64 {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        inner
            .commits
            .get(&(group.to_string(), topic.to_string()))
            .copied()
            .unwrap_or(0)
    }

    pub fn topics(&self) -> Vec<String> {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        let mut v: Vec<String> = inner.topics.keys().cloned().collect();
        v.sort();
        v
    }

    /// Truncate entries below `offset` on a topic (retention), returning
    /// freed bytes. Offsets remain stable: the log keeps logical offsets.
    pub fn truncate(&self, topic: &str, below: u64) -> usize {
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let Some(log) = inner.topics.get_mut(topic) else { return 0 };
        let mut freed = 0;
        // Replace truncated payloads with empty bytes, keeping offsets dense.
        for e in log.iter_mut().filter(|e| e.offset < below) {
            freed += e.payload.0.len();
            e.payload = Bytes(Vec::new());
        }
        self.gauge.sub(freed);
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_assigns_dense_offsets() {
        let b = BrokerState::new();
        assert_eq!(b.produce("t", Bytes(vec![1])), 0);
        assert_eq!(b.produce("t", Bytes(vec![2])), 1);
        assert_eq!(b.produce("u", Bytes(vec![3])), 0);
        assert_eq!(b.end_offset("t"), 2);
        assert_eq!(b.topics(), vec!["t".to_string(), "u".to_string()]);
    }

    #[test]
    fn fetch_returns_in_order() {
        let b = BrokerState::new();
        for i in 0..5u8 {
            b.produce("t", Bytes(vec![i]));
        }
        let entries = b.fetch("t", 1, 2, Duration::ZERO);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].offset, 1);
        assert_eq!(entries[1].payload, Bytes(vec![2]));
        assert!(b.fetch("t", 5, 10, Duration::ZERO).is_empty());
    }

    #[test]
    fn fetch_long_poll_wakes_on_produce() {
        let b = BrokerState::new();
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            b2.fetch("t", 0, 10, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        b.produce("t", Bytes(vec![9]));
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].payload, Bytes(vec![9]));
    }

    #[test]
    fn fetch_timeout_returns_empty() {
        let b = BrokerState::new();
        let t0 = Instant::now();
        let got = b.fetch("t", 0, 1, Duration::from_millis(25));
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn commits_per_group() {
        let b = BrokerState::new();
        assert_eq!(b.committed("g1", "t"), 0);
        b.commit("g1", "t", 5);
        b.commit("g2", "t", 2);
        assert_eq!(b.committed("g1", "t"), 5);
        assert_eq!(b.committed("g2", "t"), 2);
    }

    #[test]
    fn truncate_frees_bytes_keeps_offsets() {
        let b = BrokerState::new();
        for _ in 0..4 {
            b.produce("t", Bytes(vec![0; 100]));
        }
        assert_eq!(b.gauge.get(), 400);
        let freed = b.truncate("t", 2);
        assert_eq!(freed, 200);
        assert_eq!(b.gauge.get(), 200);
        // Offsets still line up after truncation.
        let entries = b.fetch("t", 2, 10, Duration::ZERO);
        assert_eq!(entries[0].offset, 2);
        assert_eq!(entries[0].payload.0.len(), 100);
    }
}
