//! Broker storage engine: append-only partitioned topic logs plus
//! consumer-group offsets.
//!
//! A topic is a set of numbered partitions, each an independent
//! append-only log with its own dense offset space. The classic
//! single-log API (`produce`/`fetch`/...) operates on partition 0, so
//! unpartitioned callers are just the one-partition special case.
//! Commits are tracked per `(group, topic, partition)`.
//!
//! The engine is optionally **durable** ([`BrokerState::open_durable`]):
//! each `(topic, partition)` gets its own segmented on-disk log (the WAL
//! sequence number *is* the partition offset, so records are
//! offset-indexed by construction), retention drops whole oldest
//! segments by count/bytes, and committed offsets checkpoint to a single
//! `commits.ckpt` file rewritten atomically on every commit. Recovery
//! replays every partition directory; offsets whose segments were
//! reclaimed by retention come back as blanked (empty-payload) entries,
//! mirroring [`BrokerState::truncate_part`]'s in-memory semantics.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::codec::{get_varint, put_varint, Bytes, Reader};
use crate::error::Result;
use crate::metrics::StoreBytes;
use crate::persist::{crc32, DurabilityOptions, RecoveryStats, Wal};

/// One log entry (offset is partition-local and dense from 0).
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    pub offset: u64,
    pub payload: Bytes,
}

/// A fetch request against one partition: `(topic, partition, offset,
/// max)`. [`BrokerState::fetch_many`] serves a whole slice of these in one
/// lock acquisition (and one wire frame over TCP).
pub type FetchReq = (String, u32, u64, u32);

#[derive(Default)]
struct Inner {
    /// topic -> partition -> log. Nested (rather than a `(String, u32)`
    /// key) so the fetch hot path — re-probed on every long-poll wake —
    /// looks up by `&str` without allocating a key.
    topics: HashMap<String, HashMap<u32, Vec<LogEntry>>>,
    /// (group, topic, partition) -> committed offset.
    commits: HashMap<(String, String, u32), u64>,
}

impl Inner {
    fn log(&self, topic: &str, partition: u32) -> Option<&Vec<LogEntry>> {
        self.topics.get(topic).and_then(|parts| parts.get(&partition))
    }

    fn slice(&self, topic: &str, partition: u32, offset: u64, max: u32) -> Vec<LogEntry> {
        match self.log(topic, partition) {
            Some(log) if (log.len() as u64) > offset => {
                let start = offset as usize;
                let end = (start + max as usize).min(log.len());
                log[start..end].to_vec()
            }
            _ => Vec::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Durability: per-partition log segments + committed-offset checkpoint
// ---------------------------------------------------------------------------

const CKPT_MAGIC: &[u8; 8] = b"PXCKPT1\n";

/// Topic names become directory names via lowercase hex (any byte is
/// path-safe, and the mapping is reversible for recovery).
fn hex_encode(s: &str) -> String {
    let mut out = String::with_capacity(s.len() * 2);
    for b in s.bytes() {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

fn hex_decode(s: &str) -> Option<String> {
    if s.len() % 2 != 0 {
        return None;
    }
    let mut bytes = Vec::with_capacity(s.len() / 2);
    for i in (0..s.len()).step_by(2) {
        bytes.push(u8::from_str_radix(s.get(i..i + 2)?, 16).ok()?);
    }
    String::from_utf8(bytes).ok()
}

fn encode_commits(commits: &HashMap<(String, String, u32), u64>) -> Vec<u8> {
    let mut body = Vec::new();
    put_varint(&mut body, commits.len() as u64);
    for ((group, topic, part), offset) in commits {
        put_varint(&mut body, group.len() as u64);
        body.extend_from_slice(group.as_bytes());
        put_varint(&mut body, topic.len() as u64);
        body.extend_from_slice(topic.as_bytes());
        put_varint(&mut body, *part as u64);
        put_varint(&mut body, *offset);
    }
    let mut buf = Vec::with_capacity(body.len() + 12);
    buf.extend_from_slice(CKPT_MAGIC);
    buf.extend_from_slice(&body);
    buf.extend_from_slice(&crc32(&body).to_le_bytes());
    buf
}

/// Load the committed-offset checkpoint; a missing, truncated or
/// CRC-damaged file yields the empty map (commits are resumable hints,
/// not data of record — consumers re-read from the last good commit).
fn read_commits(path: &Path) -> HashMap<(String, String, u32), u64> {
    let Ok(buf) = fs::read(path) else {
        return HashMap::new();
    };
    let head = CKPT_MAGIC.len();
    if buf.len() < head + 4 || &buf[..head] != CKPT_MAGIC {
        return HashMap::new();
    }
    let body = &buf[head..buf.len() - 4];
    let crc = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
    if crc32(body) != crc {
        return HashMap::new();
    }
    let mut out = HashMap::new();
    let mut r = Reader::new(body);
    let parse = (|| -> Result<()> {
        let n = get_varint(&mut r)?;
        for _ in 0..n {
            let glen = get_varint(&mut r)? as usize;
            let group = String::from_utf8_lossy(r.take(glen)?).into_owned();
            let tlen = get_varint(&mut r)? as usize;
            let topic = String::from_utf8_lossy(r.take(tlen)?).into_owned();
            let part = get_varint(&mut r)? as u32;
            let offset = get_varint(&mut r)?;
            out.insert((group, topic, part), offset);
        }
        Ok(())
    })();
    if parse.is_err() {
        return HashMap::new();
    }
    out
}

/// Durability sidecar of a broker engine: one [`Wal`] per open
/// `(topic, partition)` plus the commit checkpoint. Shared by clones.
struct BrokerPersist {
    /// `<data_dir>/broker`.
    root: PathBuf,
    opts: DurabilityOptions,
    /// Lazily opened partition logs.
    logs: Mutex<HashMap<(String, u32), Arc<Wal>>>,
    /// Serializes checkpoint writers so a later commit's snapshot cannot
    /// be overwritten by an earlier one still in flight.
    ckpt: Mutex<()>,
    recovery: RecoveryStats,
}

impl BrokerPersist {
    fn part_dir(&self, topic: &str, partition: u32) -> PathBuf {
        self.root
            .join("topics")
            .join(hex_encode(topic))
            .join(format!("p{partition}"))
    }

    /// Open (or create) the log for one partition. The fresh-partition
    /// case starts at seq 0, matching the empty in-memory log's first
    /// offset; recovered partitions were pre-registered at open.
    fn wal_for(&self, topic: &str, partition: u32) -> Result<Arc<Wal>> {
        let mut logs = self.logs.lock().unwrap();
        let key = (topic.to_string(), partition);
        if let Some(w) = logs.get(&key) {
            return Ok(w.clone());
        }
        let dir = self.part_dir(topic, partition);
        fs::create_dir_all(&dir)?;
        let wal = Arc::new(Wal::open(
            &dir,
            0,
            self.opts.segment_bytes,
            self.opts.fsync,
        )?);
        logs.insert(key, wal.clone());
        Ok(wal)
    }

    fn write_commits(
        &self,
        commits: &HashMap<(String, String, u32), u64>,
    ) -> Result<()> {
        let path = self.root.join("commits.ckpt");
        let tmp = self.root.join(".commits.ckpt.tmp");
        fs::write(&tmp, encode_commits(commits))?;
        fs::File::open(&tmp)?.sync_all()?;
        fs::rename(&tmp, &path)?;
        fs::File::open(&self.root)?.sync_all()?;
        Ok(())
    }
}

/// Embedded broker engine; cheap to clone.
#[derive(Clone)]
pub struct BrokerState {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    /// Bytes resident across all topic logs (event metadata is small, but
    /// the Fig 6 "data through the broker" baseline pushes bulk here).
    pub gauge: Arc<StoreBytes>,
    /// `Some` when topic logs write through to a data dir.
    persist: Option<Arc<BrokerPersist>>,
}

impl Default for BrokerState {
    fn default() -> Self {
        Self::new()
    }
}

impl BrokerState {
    pub fn new() -> Self {
        BrokerState {
            inner: Arc::new((Mutex::new(Inner::default()), Condvar::new())),
            gauge: StoreBytes::new(),
            persist: None,
        }
    }

    /// Open a durable broker rooted at `opts.data_dir/broker`: replay
    /// every `(topic, partition)` log directory and the commit
    /// checkpoint, then write through all subsequent produces/commits.
    pub fn open_durable(opts: &DurabilityOptions) -> Result<BrokerState> {
        let root = opts.data_dir.join("broker");
        let topics_dir = root.join("topics");
        fs::create_dir_all(&topics_dir)?;

        let mut topics: HashMap<String, HashMap<u32, Vec<LogEntry>>> =
            HashMap::new();
        let mut logs: HashMap<(String, u32), Arc<Wal>> = HashMap::new();
        let mut resident = 0usize;
        let mut replayed = 0u64;
        let mut truncated = 0u64;
        for tdir in fs::read_dir(&topics_dir)? {
            let tdir = tdir?.path();
            let Some(topic) = tdir
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(hex_decode)
                .filter(|_| tdir.is_dir())
            else {
                continue;
            };
            for pdir in fs::read_dir(&tdir)? {
                let pdir = pdir?.path();
                let Some(partition) = pdir
                    .file_name()
                    .and_then(|n| n.to_str())
                    .and_then(|n| n.strip_prefix('p'))
                    .and_then(|n| n.parse::<u32>().ok())
                    .filter(|_| pdir.is_dir())
                else {
                    continue;
                };
                let mut entries: Vec<LogEntry> = Vec::new();
                let stats = Wal::replay(&pdir, 0, |seq, payload| {
                    // Retention may have dropped prefix segments: blank
                    // the gap so offsets stay dense (same semantics as
                    // an in-memory truncate_part).
                    while (entries.len() as u64) < seq {
                        entries.push(LogEntry {
                            offset: entries.len() as u64,
                            payload: Bytes(Vec::new()),
                        });
                    }
                    resident += payload.len();
                    entries.push(LogEntry {
                        offset: seq,
                        payload: Bytes(payload.to_vec()),
                    });
                })?;
                replayed += stats.replayed;
                truncated += stats.truncated;
                let wal = Wal::open(
                    &pdir,
                    stats.next_seq,
                    opts.segment_bytes,
                    opts.fsync,
                )?;
                logs.insert((topic.clone(), partition), Arc::new(wal));
                topics
                    .entry(topic.clone())
                    .or_default()
                    .insert(partition, entries);
            }
        }
        let commits = read_commits(&root.join("commits.ckpt"));
        let gauge = StoreBytes::new();
        gauge.add(resident);
        Ok(BrokerState {
            inner: Arc::new((
                Mutex::new(Inner { topics, commits }),
                Condvar::new(),
            )),
            gauge,
            persist: Some(Arc::new(BrokerPersist {
                root,
                opts: opts.clone(),
                logs: Mutex::new(logs),
                ckpt: Mutex::new(()),
                recovery: RecoveryStats {
                    snapshot_seq: None,
                    replayed_records: replayed,
                    truncated_records: truncated,
                },
            })),
        })
    }

    /// What recovery found at open, or `None` for a RAM-only broker.
    pub fn recovery_stats(&self) -> Option<RecoveryStats> {
        self.persist.as_ref().map(|p| p.recovery)
    }

    /// True when topic logs write through to a data dir.
    pub fn is_durable(&self) -> bool {
        self.persist.is_some()
    }

    /// Append one produce record under the engine lock (the WAL seq is
    /// the partition offset). Fail-stop: an engine that cannot log a
    /// produce must not ack it.
    fn log_produce(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        payload: &[u8],
    ) -> Option<(Arc<Wal>, u64)> {
        let p = self.persist.as_ref()?;
        let wal = p.wal_for(topic, partition).unwrap_or_else(|e| {
            panic!("broker wal open failed (fail-stop): {e}")
        });
        let seq = wal.append(payload).unwrap_or_else(|e| {
            panic!("broker wal append failed (fail-stop): {e}")
        });
        debug_assert_eq!(seq, offset, "wal seq must equal partition offset");
        Some((wal, seq))
    }

    /// Group-commit the last logged record of a produce batch (after the
    /// engine lock is released, before acking), then apply retention.
    fn commit_logged(&self, logged: Option<(Arc<Wal>, u64)>) {
        let Some(p) = self.persist.as_ref() else { return };
        let Some((wal, seq)) = logged else { return };
        if let Err(e) = wal.commit(seq) {
            panic!("broker wal commit failed (fail-stop): {e}");
        }
        if let Err(e) = wal.retain(p.opts.retain_segments, p.opts.retain_bytes)
        {
            panic!("broker wal retention failed (fail-stop): {e}");
        }
    }

    /// Force buffered partition logs to disk (clean shutdown aid).
    pub fn persist_sync(&self) {
        if let Some(p) = self.persist.as_ref() {
            let logs: Vec<Arc<Wal>> =
                p.logs.lock().unwrap().values().cloned().collect();
            for wal in logs {
                if let Err(e) = wal.sync() {
                    panic!("broker wal sync failed (fail-stop): {e}");
                }
            }
        }
    }

    /// Append to partition 0; returns the assigned offset.
    pub fn produce(&self, topic: &str, payload: Bytes) -> u64 {
        self.produce_to(topic, 0, payload)
    }

    /// Append to a specific partition; returns the assigned offset.
    pub fn produce_to(&self, topic: &str, partition: u32, payload: Bytes) -> u64 {
        let (m, cv) = &*self.inner;
        let (offset, logged) = {
            let mut inner = m.lock().unwrap();
            self.gauge.add(payload.0.len());
            let log = inner
                .topics
                .entry(topic.to_string())
                .or_default()
                .entry(partition)
                .or_default();
            let offset = log.len() as u64;
            let logged = self.log_produce(topic, partition, offset, &payload.0);
            log.push(LogEntry { offset, payload });
            cv.notify_all();
            (offset, logged)
        };
        self.commit_logged(logged);
        offset
    }

    /// Append a batch to one partition under a single lock acquisition and
    /// a single waiter wake-up; returns the assigned offsets (dense).
    pub fn produce_many(
        &self,
        topic: &str,
        partition: u32,
        payloads: Vec<Bytes>,
    ) -> Vec<u64> {
        if payloads.is_empty() {
            return Vec::new();
        }
        let (m, cv) = &*self.inner;
        let (offsets, logged) = {
            let mut inner = m.lock().unwrap();
            let mut logged = None;
            let log = inner
                .topics
                .entry(topic.to_string())
                .or_default()
                .entry(partition)
                .or_default();
            let mut offsets = Vec::with_capacity(payloads.len());
            let mut bytes = 0usize;
            for payload in payloads {
                bytes += payload.0.len();
                let offset = log.len() as u64;
                // One WAL record per entry; the batch group-commits once
                // below (one fsync covers the whole produce).
                logged = self
                    .log_produce(topic, partition, offset, &payload.0)
                    .or(logged);
                log.push(LogEntry { offset, payload });
                offsets.push(offset);
            }
            self.gauge.add(bytes);
            cv.notify_all();
            (offsets, logged)
        };
        self.commit_logged(logged);
        offsets
    }

    /// Fetch up to `max` entries from partition 0 (see
    /// [`BrokerState::fetch_from`]).
    pub fn fetch(
        &self,
        topic: &str,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Vec<LogEntry> {
        self.fetch_from(topic, 0, offset, max, timeout)
    }

    /// Fetch up to `max` entries of a partition from `offset`, long-polling
    /// up to `timeout` for at least one entry (`Duration::ZERO` = no wait).
    pub fn fetch_from(
        &self,
        topic: &str,
        partition: u32,
        offset: u64,
        max: u32,
        timeout: Duration,
    ) -> Vec<LogEntry> {
        if max == 0 {
            // A zero-entry request can never be satisfied; don't park the
            // caller on the long poll.
            return Vec::new();
        }
        let (m, cv) = &*self.inner;
        let deadline = Instant::now() + timeout;
        let mut inner = m.lock().unwrap();
        loop {
            let entries = inner.slice(topic, partition, offset, max);
            if !entries.is_empty() {
                return entries;
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _) = cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Multi-partition fetch: serve every request in `reqs`, long-polling
    /// up to `timeout` until at least one request has data. Results align
    /// positionally with `reqs`. This is the fan-in primitive a
    /// partitioned consumer polls its whole assignment with — one lock
    /// acquisition (one frame over TCP) instead of one per partition.
    pub fn fetch_many(&self, reqs: &[FetchReq], timeout: Duration) -> Vec<Vec<LogEntry>> {
        if reqs.is_empty() {
            return Vec::new();
        }
        // All-zero `max` can never produce an entry; answer immediately
        // instead of long-polling (zero-max members of a mixed batch are
        // simply never the wake-up reason).
        if reqs.iter().all(|(_, _, _, max)| *max == 0) {
            return vec![Vec::new(); reqs.len()];
        }
        let (m, cv) = &*self.inner;
        let deadline = Instant::now() + timeout;
        let mut inner = m.lock().unwrap();
        loop {
            let out: Vec<Vec<LogEntry>> = reqs
                .iter()
                .map(|(topic, part, offset, max)| {
                    inner.slice(topic, *part, *offset, *max)
                })
                .collect();
            if out.iter().any(|e| !e.is_empty()) {
                return out;
            }
            let now = Instant::now();
            if now >= deadline {
                return out;
            }
            let (guard, _) = cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    pub fn end_offset(&self, topic: &str) -> u64 {
        self.end_offset_of(topic, 0)
    }

    pub fn end_offset_of(&self, topic: &str, partition: u32) -> u64 {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        inner
            .log(topic, partition)
            .map(|log| log.len() as u64)
            .unwrap_or(0)
    }

    pub fn commit(&self, group: &str, topic: &str, offset: u64) {
        self.commit_part(group, topic, 0, offset);
    }

    pub fn commit_part(&self, group: &str, topic: &str, partition: u32, offset: u64) {
        let (m, _) = &*self.inner;
        let key = (group.to_string(), topic.to_string(), partition);
        match self.persist.as_ref() {
            None => {
                m.lock().unwrap().commits.insert(key, offset);
            }
            Some(p) => {
                // Hold the checkpoint latch across snapshot + write so a
                // later commit's image can never be clobbered by an
                // earlier one still in flight.
                let _serialize = p.ckpt.lock().unwrap();
                let commits = {
                    let mut inner = m.lock().unwrap();
                    inner.commits.insert(key, offset);
                    inner.commits.clone()
                };
                if let Err(e) = p.write_commits(&commits) {
                    panic!("broker commit checkpoint failed (fail-stop): {e}");
                }
            }
        }
    }

    pub fn committed(&self, group: &str, topic: &str) -> u64 {
        self.committed_part(group, topic, 0)
    }

    pub fn committed_part(&self, group: &str, topic: &str, partition: u32) -> u64 {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        inner
            .commits
            .get(&(group.to_string(), topic.to_string(), partition))
            .copied()
            .unwrap_or(0)
    }

    pub fn topics(&self) -> Vec<String> {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        let mut v: Vec<String> = inner.topics.keys().cloned().collect();
        v.sort();
        v
    }

    /// Partitions of a topic that hold at least one entry, ascending.
    pub fn partitions(&self, topic: &str) -> Vec<u32> {
        let (m, _) = &*self.inner;
        let inner = m.lock().unwrap();
        let mut v: Vec<u32> = inner
            .topics
            .get(topic)
            .map(|parts| parts.keys().copied().collect())
            .unwrap_or_default();
        v.sort_unstable();
        v
    }

    /// Truncate entries below `offset` on partition 0 (see
    /// [`BrokerState::truncate_part`]).
    pub fn truncate(&self, topic: &str, below: u64) -> usize {
        self.truncate_part(topic, 0, below)
    }

    /// Truncate entries below `offset` on a partition (retention),
    /// returning freed bytes. Offsets remain stable: the log keeps logical
    /// offsets. On a durable broker this frees memory only — on-disk
    /// reclaim happens at whole-segment granularity via
    /// [`DurabilityOptions::retain_segments`] / `retain_bytes`, and
    /// recovery blanks any offsets whose segments were dropped.
    pub fn truncate_part(&self, topic: &str, partition: u32, below: u64) -> usize {
        let (m, _) = &*self.inner;
        let mut inner = m.lock().unwrap();
        let Some(log) = inner
            .topics
            .get_mut(topic)
            .and_then(|parts| parts.get_mut(&partition))
        else {
            return 0;
        };
        let mut freed = 0;
        // Replace truncated payloads with empty bytes, keeping offsets dense.
        for e in log.iter_mut().filter(|e| e.offset < below) {
            freed += e.payload.0.len();
            e.payload = Bytes(Vec::new());
        }
        self.gauge.sub(freed);
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_assigns_dense_offsets() {
        let b = BrokerState::new();
        assert_eq!(b.produce("t", Bytes(vec![1])), 0);
        assert_eq!(b.produce("t", Bytes(vec![2])), 1);
        assert_eq!(b.produce("u", Bytes(vec![3])), 0);
        assert_eq!(b.end_offset("t"), 2);
        assert_eq!(b.topics(), vec!["t".to_string(), "u".to_string()]);
    }

    #[test]
    fn partitions_are_independent_logs() {
        let b = BrokerState::new();
        assert_eq!(b.produce_to("t", 0, Bytes(vec![0])), 0);
        assert_eq!(b.produce_to("t", 1, Bytes(vec![1])), 0);
        assert_eq!(b.produce_to("t", 1, Bytes(vec![2])), 1);
        assert_eq!(b.end_offset_of("t", 0), 1);
        assert_eq!(b.end_offset_of("t", 1), 2);
        assert_eq!(b.end_offset_of("t", 7), 0);
        assert_eq!(b.partitions("t"), vec![0, 1]);
        // Topic list dedups across partitions.
        assert_eq!(b.topics(), vec!["t".to_string()]);
        let got = b.fetch_from("t", 1, 0, 10, Duration::ZERO);
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].payload, Bytes(vec![2]));
    }

    #[test]
    fn produce_many_is_dense_and_gauged() {
        let b = BrokerState::new();
        b.produce_to("t", 3, Bytes(vec![9; 10]));
        let offs = b.produce_many(
            "t",
            3,
            vec![Bytes(vec![0; 5]), Bytes(vec![1; 5]), Bytes(vec![2; 5])],
        );
        assert_eq!(offs, vec![1, 2, 3]);
        assert_eq!(b.gauge.get(), 25);
        assert!(b.produce_many("t", 3, Vec::new()).is_empty());
    }

    #[test]
    fn fetch_returns_in_order() {
        let b = BrokerState::new();
        for i in 0..5u8 {
            b.produce("t", Bytes(vec![i]));
        }
        let entries = b.fetch("t", 1, 2, Duration::ZERO);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].offset, 1);
        assert_eq!(entries[1].payload, Bytes(vec![2]));
        assert!(b.fetch("t", 5, 10, Duration::ZERO).is_empty());
    }

    #[test]
    fn fetch_long_poll_wakes_on_produce() {
        let b = BrokerState::new();
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            b2.fetch("t", 0, 10, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        b.produce("t", Bytes(vec![9]));
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].payload, Bytes(vec![9]));
    }

    #[test]
    fn fetch_many_aligns_and_wakes_on_any_partition() {
        let b = BrokerState::new();
        b.produce_to("t", 0, Bytes(vec![1]));
        let reqs: Vec<FetchReq> = vec![
            ("t".into(), 0, 0, 10),
            ("t".into(), 1, 0, 10),
            ("u".into(), 0, 0, 10),
        ];
        let got = b.fetch_many(&reqs, Duration::ZERO);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].len(), 1);
        assert!(got[1].is_empty() && got[2].is_empty());

        // Long poll returns as soon as any requested partition has data.
        let b2 = b.clone();
        let reqs2: Vec<FetchReq> =
            vec![("t".into(), 1, 0, 10), ("t".into(), 2, 0, 10)];
        let h = std::thread::spawn(move || {
            b2.fetch_many(&reqs2, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        b.produce_to("t", 2, Bytes(vec![7]));
        let got = h.join().unwrap();
        assert!(got[0].is_empty());
        assert_eq!(got[1].len(), 1);
        assert_eq!(got[1][0].payload, Bytes(vec![7]));

        // Empty request set returns immediately.
        assert!(b.fetch_many(&[], Duration::from_secs(5)).is_empty());
    }

    #[test]
    fn zero_max_fetch_returns_immediately() {
        let b = BrokerState::new();
        b.produce("t", Bytes(vec![1]));
        let t0 = Instant::now();
        assert!(b.fetch("t", 0, 0, Duration::from_secs(5)).is_empty());
        let reqs: Vec<FetchReq> =
            vec![("t".into(), 0, 0, 0), ("u".into(), 0, 0, 0)];
        let got = b.fetch_many(&reqs, Duration::from_secs(5));
        assert_eq!(got, vec![Vec::new(), Vec::new()]);
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "zero-max fetch must not long-poll"
        );
    }

    #[test]
    fn fetch_timeout_returns_empty() {
        let b = BrokerState::new();
        let t0 = Instant::now();
        let got = b.fetch("t", 0, 1, Duration::from_millis(25));
        assert!(got.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn commits_per_group_and_partition() {
        let b = BrokerState::new();
        assert_eq!(b.committed("g1", "t"), 0);
        b.commit("g1", "t", 5);
        b.commit("g2", "t", 2);
        assert_eq!(b.committed("g1", "t"), 5);
        assert_eq!(b.committed("g2", "t"), 2);
        // Partitioned commits are independent of partition 0's.
        b.commit_part("g1", "t", 4, 9);
        assert_eq!(b.committed_part("g1", "t", 4), 9);
        assert_eq!(b.committed("g1", "t"), 5);
    }

    #[test]
    fn truncate_frees_bytes_keeps_offsets() {
        let b = BrokerState::new();
        for _ in 0..4 {
            b.produce("t", Bytes(vec![0; 100]));
        }
        assert_eq!(b.gauge.get(), 400);
        let freed = b.truncate("t", 2);
        assert_eq!(freed, 200);
        assert_eq!(b.gauge.get(), 200);
        // Offsets still line up after truncation.
        let entries = b.fetch("t", 2, 10, Duration::ZERO);
        assert_eq!(entries[0].offset, 2);
        assert_eq!(entries[0].payload.0.len(), 100);
    }

    fn durable_opts(tag: &str) -> DurabilityOptions {
        let dir = std::env::temp_dir().join(format!(
            "pallas-brstate-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        DurabilityOptions::new(dir).fsync(crate::persist::FsyncPolicy::Off)
    }

    #[test]
    fn hex_roundtrip() {
        for name in ["t", "orders/us-east", "日本語", ""] {
            assert_eq!(hex_decode(&hex_encode(name)).as_deref(), Some(name));
        }
        assert!(hex_decode("zz").is_none());
        assert!(hex_decode("abc").is_none());
    }

    #[test]
    fn durable_topics_and_commits_survive_reopen() {
        let opts = durable_opts("reopen");
        let b = BrokerState::open_durable(&opts).unwrap();
        assert!(b.is_durable());
        for i in 0..8u8 {
            b.produce_to("orders", 0, Bytes(vec![i; 32]));
        }
        b.produce_many(
            "orders",
            1,
            vec![Bytes(vec![100; 16]), Bytes(vec![101; 16])],
        );
        b.produce_to("audit", 3, Bytes(vec![9; 8]));
        b.commit_part("g1", "orders", 0, 5);
        b.commit_part("g1", "orders", 1, 2);
        b.commit_part("g2", "audit", 3, 1);
        b.persist_sync();
        drop(b);

        let b = BrokerState::open_durable(&opts).unwrap();
        let stats = b.recovery_stats().unwrap();
        assert_eq!(stats.replayed_records, 11);
        assert_eq!(stats.truncated_records, 0);
        assert_eq!(b.end_offset_of("orders", 0), 8);
        assert_eq!(b.end_offset_of("orders", 1), 2);
        assert_eq!(b.end_offset_of("audit", 3), 1);
        let got = b.fetch_from("orders", 0, 3, 2, Duration::ZERO);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], LogEntry { offset: 3, payload: Bytes(vec![3; 32]) });
        assert_eq!(b.committed_part("g1", "orders", 0), 5);
        assert_eq!(b.committed_part("g1", "orders", 1), 2);
        assert_eq!(b.committed_part("g2", "audit", 3), 1);
        assert_eq!(b.committed_part("g9", "orders", 0), 0);
        // Offsets continue densely after recovery.
        assert_eq!(b.produce_to("orders", 0, Bytes(vec![42])), 8);
        b.persist_sync();
        drop(b);
        let b = BrokerState::open_durable(&opts).unwrap();
        assert_eq!(b.end_offset_of("orders", 0), 9);
        let _ = std::fs::remove_dir_all(&opts.data_dir);
    }

    #[test]
    fn durable_retention_blanks_reclaimed_prefix() {
        // Tiny segments + keep only 1 closed segment: early records'
        // segments get dropped on produce; recovery blanks the gap but
        // keeps offsets dense and the tail intact.
        let opts = durable_opts("retain").segment_bytes(4096).retain_segments(1);
        let b = BrokerState::open_durable(&opts).unwrap();
        for i in 0..64u8 {
            b.produce_to("t", 0, Bytes(vec![i; 512]));
        }
        b.persist_sync();
        drop(b);

        let b = BrokerState::open_durable(&opts).unwrap();
        assert_eq!(b.end_offset_of("t", 0), 64, "offsets stay dense");
        let all = b.fetch_from("t", 0, 0, 64, Duration::ZERO);
        assert_eq!(all.len(), 64);
        assert!(
            all.first().unwrap().payload.0.is_empty(),
            "reclaimed prefix comes back blanked"
        );
        let last = all.last().unwrap();
        assert_eq!(last.offset, 63);
        assert_eq!(last.payload, Bytes(vec![63; 512]));
        let _ = std::fs::remove_dir_all(&opts.data_dir);
    }

    #[test]
    fn corrupt_commit_checkpoint_degrades_to_empty() {
        let opts = durable_opts("ckpt");
        let b = BrokerState::open_durable(&opts).unwrap();
        b.commit_part("g", "t", 0, 7);
        drop(b);
        let path = opts.data_dir.join("broker").join("commits.ckpt");
        let mut buf = std::fs::read(&path).unwrap();
        let n = buf.len();
        buf[n - 1] ^= 0xFF; // break the CRC
        std::fs::write(&path, &buf).unwrap();
        let b = BrokerState::open_durable(&opts).unwrap();
        assert_eq!(b.committed_part("g", "t", 0), 0);
        let _ = std::fs::remove_dir_all(&opts.data_dir);
    }
}
