//! MOF Generation workflow (paper Sec II & VI, Fig 10).
//!
//! A central *thinker* steers rounds of generate → assemble → score tasks:
//! diffusion-model generators emit ligand feature blocks, assembly
//! combines ligands into MOF candidates, and a physics surrogate (the L1
//! `mof_score` Pallas kernel, compiled to the `mof_score_c256` PJRT
//! artifact) ranks candidates for CO₂ uptake. All task inputs/outputs
//! larger than 1 kB travel as proxies (the paper's deployment policy).
//!
//! Fig 10's measurement: the number of *active proxies* (proxied objects
//! whose target is still stored) over the application's runtime, under
//! the default proxy model (nothing is ever freed) vs the ownership model
//! (owners/borrows drop → automatic eviction).

use std::sync::Arc;
use std::time::Instant;

use crate::codec::{Bytes, Encode, F32s};
use crate::engine::{ClusterConfig, LocalCluster, StoreExecutor, TaskArg};
use crate::error::{Error, Result};
use crate::ownership::StoreOwnedExt;
use crate::rng::Rng;
use crate::runtime::ModelRegistry;
use crate::store::Store;

/// Memory-management mode under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryMode {
    /// Plain proxies; targets are never freed (ProxyStore default).
    Default,
    /// Ownership model: automatic eviction via owned/borrowed proxies.
    Ownership,
}

impl MemoryMode {
    pub fn label(&self) -> &'static str {
        match self {
            MemoryMode::Default => "default",
            MemoryMode::Ownership => "ownership",
        }
    }
}

/// Workload knobs.
#[derive(Debug, Clone)]
pub struct MofConfig {
    /// Thinker rounds.
    pub rounds: usize,
    /// Generator tasks per round.
    pub generators: usize,
    /// Ligand feature block size (candidates × dims must match the
    /// compiled artifact: 256 × 64).
    pub candidates: usize,
    pub dims: usize,
    /// Keep-top-k candidates per round in the thinker state.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for MofConfig {
    fn default() -> Self {
        MofConfig {
            rounds: 6,
            generators: 3,
            candidates: 256,
            dims: 64,
            top_k: 8,
            seed: 2024,
        }
    }
}

/// Sampled (time, active-proxies, store-bytes) series.
#[derive(Debug, Clone, Default)]
pub struct ProxySeries {
    pub samples: Vec<(f64, i64, i64)>,
}

impl ProxySeries {
    pub fn peak_active(&self) -> i64 {
        self.samples.iter().map(|s| s.1).max().unwrap_or(0)
    }

    pub fn final_active(&self) -> i64 {
        self.samples.last().map(|s| s.1).unwrap_or(0)
    }

    pub fn csv_rows(&self) -> Vec<String> {
        self.samples
            .iter()
            .map(|(t, a, b)| format!("{t:.3},{a},{b}"))
            .collect()
    }
}

/// Run report.
#[derive(Debug, Clone)]
pub struct MofReport {
    pub series: ProxySeries,
    /// Best (score, round) found — correctness/steering signal.
    pub best_score: f32,
    pub rounds: usize,
}

/// Generate one ligand feature block (the diffusion-model stand-in).
pub fn generate_ligands(rng: &mut Rng, candidates: usize, dims: usize) -> Vec<f32> {
    (0..candidates * dims)
        .map(|_| (rng.normal() * 0.5) as f32)
        .collect()
}

/// Assemble: combine two ligand blocks into a candidate feature block.
pub fn assemble(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b).map(|(x, y)| 0.5 * (x + y)).collect()
}

/// Number of active proxied objects (objects resident in the channel).
fn active_proxies(store: &Store) -> i64 {
    store.connector().len().unwrap_or(0) as i64
}

/// Run the MOF campaign under a memory mode, sampling active proxies.
pub fn run(
    cfg: &MofConfig,
    reg: &Arc<ModelRegistry>,
    mode: MemoryMode,
) -> Result<MofReport> {
    if cfg.candidates != reg.geometry("mof_candidates").unwrap_or(256) as usize
        || cfg.dims != reg.geometry("mof_dim").unwrap_or(64) as usize
    {
        return Err(Error::Config(
            "candidates/dims must match the compiled mof_score artifact".into(),
        ));
    }
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: cfg.generators + 1,
        models: Some(reg.clone()),
        ..Default::default()
    }));
    let store = Store::memory("mof");
    let executor = StoreExecutor::new(cluster, store.clone());
    let mut rng = Rng::new(cfg.seed);

    // Scoring direction ("learned" CO2-uptake direction).
    let weights: Vec<f32> = (0..cfg.dims).map(|_| rng.normal() as f32).collect();

    let t0 = Instant::now();
    let mut series = ProxySeries::default();
    let mut sample = |store: &Store| {
        series.samples.push((
            t0.elapsed().as_secs_f64(),
            active_proxies(store),
            store.gauge().map(|g| g.get()).unwrap_or(0),
        ));
    };

    let mut best_score = f32::MIN;
    // Thinker state: proxies of the current top candidates. In Default
    // mode these (and every intermediate) accumulate; in Ownership mode
    // everything but the retained top-k is evicted automatically.
    let mut retained_default: Vec<crate::proxy::Proxy<F32s>> = Vec::new();
    let mut retained_owned: Vec<crate::ownership::OwnedProxy<F32s>> =
        Vec::new();

    for round in 0..cfg.rounds {
        sample(&store);
        // 1) Generate ligand blocks in parallel tasks.
        let gen_futs: Vec<_> = (0..cfg.generators)
            .map(|g| {
                let seed = cfg.seed ^ ((round * 131 + g) as u64);
                let (c, d) = (cfg.candidates, cfg.dims);
                executor.submit::<F32s>(
                    vec![TaskArg::Value(Bytes((seed).to_bytes()))],
                    Box::new(move |_ctx, args| {
                        let seed: u64 = args[0].get()?;
                        let mut rng = Rng::new(seed);
                        Ok(F32s(generate_ligands(&mut rng, c, d)).to_bytes())
                    }),
                )
            })
            .collect();
        let ligands: Vec<Vec<f32>> = gen_futs
            .iter()
            .map(|f| f.result().map(|x| x.0))
            .collect::<Result<_>>()?;
        sample(&store);

        // 2) Assemble pairs (ring) and score each via the PJRT artifact.
        for i in 0..ligands.len() {
            let a = &ligands[i];
            let b = &ligands[(i + 1) % ligands.len()];
            let candidate = F32s(assemble(a, b));

            // The candidate block is a large object: proxy it per policy.
            let (cand_arg, owned) = match mode {
                MemoryMode::Default => {
                    let p = store.proxy(&candidate)?;
                    retained_default.push(p.clone());
                    (TaskArg::Proxied(Bytes(p.to_bytes())), None)
                }
                MemoryMode::Ownership => {
                    let o = store.owned_proxy(&candidate)?;
                    (executor.make_borrowed(&o)?, Some(o))
                }
            };
            let w_arg = executor.make_arg(&F32s(weights.clone()))?;
            let fut = executor.submit::<F32s>(
                vec![cand_arg, w_arg],
                Box::new(move |ctx, args| {
                    let reg = ctx
                        .models
                        .as_ref()
                        .ok_or_else(|| Error::Config("no models".into()))?;
                    let cand: F32s = args[0].get()?;
                    let w: F32s = args[1].get()?;
                    let scores = reg.execute_f32(
                        "mof_score_c256",
                        &[&cand.0, &w.0],
                    )?;
                    Ok(F32s(scores[0].clone()).to_bytes())
                }),
            );
            let scores = fut.result()?.0;
            let round_best = scores.iter().cloned().fold(f32::MIN, f32::max);
            best_score = best_score.max(round_best);

            // Thinker retention: keep the candidate if it made the cut.
            if let Some(o) = owned {
                if round_best >= best_score {
                    retained_owned.push(o);
                    if retained_owned.len() > cfg.top_k {
                        retained_owned.remove(0); // drop → evict
                    }
                }
                // else: `o` drops here → automatic eviction.
            }
            sample(&store);
        }
    }

    // Campaign over: the thinker's working set goes out of scope.
    retained_owned.clear();
    retained_default.clear(); // plain proxies: targets remain stored!
    sample(&store);

    Ok(MofReport { series, best_score, rounds: cfg.rounds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;
    use crate::ownership::take_violations;
    use crate::runtime::default_artifacts_dir;

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::load(default_artifacts_dir()).unwrap()
    }

    fn quick() -> MofConfig {
        MofConfig { rounds: 3, generators: 2, top_k: 2, ..Default::default() }
    }

    #[test]
    fn assemble_averages() {
        assert_eq!(assemble(&[2.0, 4.0], &[0.0, 2.0]), vec![1.0, 3.0]);
    }

    #[test]
    fn default_mode_accumulates_proxies() {
        let reg = registry();
        let report = run(&quick(), &reg, MemoryMode::Default).unwrap();
        assert!(report.best_score.is_finite());
        assert!(
            report.series.final_active() >= report.series.peak_active() / 2,
            "default mode must leak: {:?}",
            report.series.final_active()
        );
        assert!(report.series.final_active() > 0);
    }

    #[test]
    fn ownership_mode_evicts_promptly() {
        let reg = registry();
        take_violations();
        let report = run(&quick(), &reg, MemoryMode::Ownership).unwrap();
        assert!(report.best_score.is_finite());
        // Executor callbacks run on worker threads; give releases a beat.
        std::thread::sleep(Duration::from_millis(100));
        assert!(
            report.series.final_active() <= 2,
            "ownership must clean up, final = {}",
            report.series.final_active()
        );
        assert_eq!(take_violations(), 0);
    }

    #[test]
    fn both_modes_find_the_same_best_score() {
        let reg = registry();
        let a = run(&quick(), &reg, MemoryMode::Default).unwrap();
        let b = run(&quick(), &reg, MemoryMode::Ownership).unwrap();
        assert!(
            (a.best_score - b.best_score).abs() < 1e-5,
            "{} vs {}",
            a.best_score,
            b.best_score
        );
    }

    #[test]
    fn ownership_peak_below_default_final() {
        let reg = registry();
        let d = run(&quick(), &reg, MemoryMode::Default).unwrap();
        let o = run(&quick(), &reg, MemoryMode::Ownership).unwrap();
        assert!(
            o.series.peak_active() < d.series.final_active(),
            "ownership peak {} !< default final {}",
            o.series.peak_active(),
            d.series.final_active()
        );
    }

    #[test]
    fn config_mismatch_rejected() {
        let reg = registry();
        let bad = MofConfig { candidates: 64, ..quick() };
        assert!(run(&bad, &reg, MemoryMode::Default).is_err());
    }
}
