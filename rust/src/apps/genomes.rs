//! 1000 Genomes mutational-overlap workflow (paper Sec II & VI, Fig 8).
//!
//! The real pipeline identifies mutational overlaps among the 2504
//! genomes of the 1000 Genomes Project. The dataset is a bulk download we
//! cannot assume, so a seeded synthetic genotype generator reproduces the
//! pipeline's *data-flow structure* faithfully (DESIGN.md §3 documents the
//! substitution): the five stages, their fan-out, their data volumes, and
//! their per-task startup overheads are all preserved.
//!
//! Stages (matching the paper's description):
//! 1. `individuals`  — per chunk: extract each individual's variant set;
//! 2. `merge`        — combine chunk results per individual group;
//! 3. `sifting`      — score variants, select those with phenotype effect;
//! 4. `overlap`      — per pair-group: mutation overlap of selected
//!                      variants between individuals;
//! 5. `frequency`    — frequency of overlapping variants.
//!
//! The whole thing compiles to a [`Pipeline`] so it runs under any
//! [`DataMode`]; Fig 8 compares `NoProxy` (the Globus-Compute-native
//! futures baseline) with `ProxyFuture`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::codec::{Decode, Encode};
use crate::engine::{ClusterConfig, LocalCluster};
use crate::error::Result;
use crate::rng::Rng;
use crate::store::Store;
use crate::workflow::{DataMode, Pipeline, PipelineTask, RunReport, WorkFn};

/// Workload scale knobs.
#[derive(Debug, Clone)]
pub struct GenomesConfig {
    /// Number of individuals (the paper's full dataset has 2504).
    pub individuals: usize,
    /// SNP count per chunk.
    pub snps_per_chunk: usize,
    /// Chunk count (stage-1 fan-out).
    pub chunks: usize,
    /// Individual groups for merge / overlap fan-out.
    pub groups: usize,
    /// Per-task startup overhead (library loading etc.).
    pub task_overhead: Duration,
    /// Per-task compute sleep floor (simulated work beyond the real
    /// computation, which is small at this scale).
    pub compute_floor: Duration,
    /// RNG seed for the synthetic genotypes.
    pub seed: u64,
}

impl Default for GenomesConfig {
    fn default() -> Self {
        GenomesConfig {
            individuals: 64,
            snps_per_chunk: 2000,
            chunks: 8,
            groups: 4,
            task_overhead: Duration::from_millis(60),
            compute_floor: Duration::from_millis(40),
            seed: 1000,
        }
    }
}

/// A genotype chunk: `snps × individuals` matrix of 0/1/2 allele counts,
/// plus the global SNP-id offset of its first row.
#[derive(Debug, Clone, PartialEq)]
pub struct Chunk {
    pub snp_offset: u32,
    pub individuals: u32,
    /// Row-major `snps × individuals`.
    pub genotypes: Vec<u8>,
}

impl Encode for Chunk {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.snp_offset.encode(buf);
        self.individuals.encode(buf);
        crate::codec::Bytes(self.genotypes.clone()).encode(buf);
    }
}

impl Decode for Chunk {
    fn decode(r: &mut crate::codec::Reader<'_>) -> Result<Self> {
        Ok(Chunk {
            snp_offset: Decode::decode(r)?,
            individuals: Decode::decode(r)?,
            genotypes: crate::codec::Bytes::decode(r)?.0,
        })
    }
}

/// Generate the synthetic dataset: `chunks` genotype chunks.
pub fn generate_dataset(cfg: &GenomesConfig) -> Vec<Chunk> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.chunks)
        .map(|c| {
            let mut genotypes =
                Vec::with_capacity(cfg.snps_per_chunk * cfg.individuals);
            for _snp in 0..cfg.snps_per_chunk {
                // Rare-variant skew: most SNPs are absent in most people.
                let maf = rng.f64() * 0.1;
                for _ind in 0..cfg.individuals {
                    let dose = if rng.chance(maf) {
                        if rng.chance(0.1) { 2 } else { 1 }
                    } else {
                        0
                    };
                    genotypes.push(dose);
                }
            }
            Chunk {
                snp_offset: (c * cfg.snps_per_chunk) as u32,
                individuals: cfg.individuals as u32,
                genotypes,
            }
        })
        .collect()
}

/// Stage 1: per-individual variant ids within one chunk.
pub fn extract_individuals(chunk: &Chunk) -> Vec<Vec<u32>> {
    let n_ind = chunk.individuals as usize;
    let mut per_ind: Vec<Vec<u32>> = vec![Vec::new(); n_ind];
    for (row, geno) in chunk.genotypes.chunks(n_ind).enumerate() {
        let snp_id = chunk.snp_offset + row as u32;
        for (ind, &g) in geno.iter().enumerate() {
            if g > 0 {
                per_ind[ind].push(snp_id);
            }
        }
    }
    per_ind
}

/// Stage 3: deterministic SIFT-like score in [0,1) per SNP; variants
/// scoring under the threshold are "selected" (phenotype-affecting).
pub fn sift_select(chunk: &Chunk, threshold: f64) -> Vec<u32> {
    let n_ind = chunk.individuals as usize;
    (0..chunk.genotypes.len() / n_ind)
        .filter_map(|row| {
            let snp_id = chunk.snp_offset + row as u32;
            // Deterministic pseudo-score derived from the SNP id.
            let mut r = Rng::new(0x5157 ^ u64::from(snp_id));
            if r.f64() < threshold {
                Some(snp_id)
            } else {
                None
            }
        })
        .collect()
}

/// Stage 4: pairwise overlap counts among a group of individuals,
/// restricted to selected variants.
pub fn mutation_overlap(
    individuals: &[Vec<u32>],
    selected: &std::collections::BTreeSet<u32>,
) -> Vec<(u32, u32, u32)> {
    let filtered: Vec<std::collections::BTreeSet<u32>> = individuals
        .iter()
        .map(|v| v.iter().copied().filter(|id| selected.contains(id)).collect())
        .collect();
    let mut out = Vec::new();
    for i in 0..filtered.len() {
        for j in (i + 1)..filtered.len() {
            let shared = filtered[i].intersection(&filtered[j]).count() as u32;
            out.push((i as u32, j as u32, shared));
        }
    }
    out
}

/// Stage 5: how many individuals carry each selected, overlapping variant.
pub fn variant_frequency(
    individuals: &[Vec<u32>],
    selected: &std::collections::BTreeSet<u32>,
) -> BTreeMap<u32, u32> {
    let mut freq = BTreeMap::new();
    for ind in individuals {
        for id in ind {
            if selected.contains(id) {
                *freq.entry(*id).or_insert(0) += 1;
            }
        }
    }
    freq.retain(|_, c| *c >= 2); // overlapping = carried by ≥2 individuals
    freq
}

/// Pure single-process reference for correctness checks.
pub fn run_reference(cfg: &GenomesConfig) -> BTreeMap<u32, u32> {
    let dataset = generate_dataset(cfg);
    let mut merged: Vec<Vec<u32>> = vec![Vec::new(); cfg.individuals];
    let mut selected = std::collections::BTreeSet::new();
    for chunk in &dataset {
        for (ind, vars) in extract_individuals(chunk).into_iter().enumerate() {
            merged[ind].extend(vars);
        }
        selected.extend(sift_select(chunk, 0.3));
    }
    variant_frequency(&merged, &selected)
}

const SIFT_THRESHOLD: f64 = 0.3;

/// Build the five-stage DAG.
///
/// Graph: chunk c → individuals(c); individuals(*) → merge(g) per group;
/// chunk c → sifting(c); merge(g) + sifting(*) → overlap(g);
/// merge(*) + sifting(*) → frequency.
pub fn build_pipeline(cfg: &GenomesConfig) -> Result<Pipeline> {
    let dataset = generate_dataset(cfg);
    let n_groups = cfg.groups.min(cfg.individuals).max(1);
    let ind_per_group = cfg.individuals.div_ceil(n_groups);
    let overhead = cfg.task_overhead;
    let compute = cfg.compute_floor;

    let mut tasks: Vec<PipelineTask> = Vec::new();

    // Stage 1: individuals, one task per chunk. Inputs: none (the chunk
    // rides inside the work closure, standing in for the "fetch" stage).
    let mut s1_ids = Vec::new();
    for (c, chunk) in dataset.iter().enumerate() {
        let chunk = chunk.clone();
        let work: WorkFn = Arc::new(move |_ctx, _inputs| {
            let per_ind = extract_individuals(&chunk);
            Ok(per_ind.to_bytes())
        });
        s1_ids.push(tasks.len());
        tasks.push(PipelineTask {
            name: format!("individuals-{c}"),
            stage: "1-individuals".into(),
            deps: vec![],
            overhead,
            compute,
            work: Some(work),
            output_bytes: 0,
        });
    }

    // Stage 2: merge, one task per individual group, over all chunks.
    let mut s2_ids = Vec::new();
    for g in 0..n_groups {
        let lo = g * ind_per_group;
        let hi = ((g + 1) * ind_per_group).min(cfg.individuals);
        let work: WorkFn = Arc::new(move |_ctx, inputs| {
            let mut merged: Vec<Vec<u32>> = vec![Vec::new(); hi - lo];
            for raw in &inputs {
                let per_ind = Vec::<Vec<u32>>::from_bytes(raw)?;
                for (ind, vars) in per_ind.iter().enumerate() {
                    if (lo..hi).contains(&ind) {
                        merged[ind - lo].extend(vars.iter().copied());
                    }
                }
            }
            Ok(merged.to_bytes())
        });
        s2_ids.push(tasks.len());
        tasks.push(PipelineTask {
            name: format!("merge-{g}"),
            stage: "2-merge".into(),
            deps: s1_ids.clone(),
            overhead,
            compute,
            work: Some(work),
            output_bytes: 0,
        });
    }

    // Stage 3: sifting, one task per chunk (no deps: works on raw chunk).
    let mut s3_ids = Vec::new();
    for (c, chunk) in dataset.iter().enumerate() {
        let chunk = chunk.clone();
        let work: WorkFn = Arc::new(move |_ctx, _inputs| {
            Ok(sift_select(&chunk, SIFT_THRESHOLD).to_bytes())
        });
        s3_ids.push(tasks.len());
        tasks.push(PipelineTask {
            name: format!("sifting-{c}"),
            stage: "3-sifting".into(),
            deps: vec![],
            overhead,
            compute,
            work: Some(work),
            output_bytes: 0,
        });
    }

    // Stage 4: overlap per group: deps = merge(g) + all sifting tasks.
    let mut s4_ids = Vec::new();
    for g in 0..n_groups {
        let mut deps = vec![s2_ids[g]];
        deps.extend(&s3_ids);
        let work: WorkFn = Arc::new(move |_ctx, inputs| {
            let merged = Vec::<Vec<u32>>::from_bytes(&inputs[0])?;
            let mut selected = std::collections::BTreeSet::new();
            for raw in &inputs[1..] {
                selected.extend(Vec::<u32>::from_bytes(raw)?);
            }
            let overlaps = mutation_overlap(&merged, &selected);
            Ok(overlaps.to_bytes())
        });
        s4_ids.push(tasks.len());
        tasks.push(PipelineTask {
            name: format!("overlap-{g}"),
            stage: "4-overlap".into(),
            deps,
            overhead,
            compute,
            work: Some(work),
            output_bytes: 0,
        });
    }

    // Stage 5: frequency over all merged groups + sifting.
    {
        let mut deps = s2_ids.clone();
        deps.extend(&s3_ids);
        let n_merge = s2_ids.len();
        let work: WorkFn = Arc::new(move |_ctx, inputs| {
            let mut individuals: Vec<Vec<u32>> = Vec::new();
            for raw in &inputs[..n_merge] {
                individuals.extend(Vec::<Vec<u32>>::from_bytes(raw)?);
            }
            let mut selected = std::collections::BTreeSet::new();
            for raw in &inputs[n_merge..] {
                selected.extend(Vec::<u32>::from_bytes(raw)?);
            }
            let freq = variant_frequency(&individuals, &selected);
            Ok(freq.to_bytes())
        });
        tasks.push(PipelineTask {
            name: "frequency".into(),
            stage: "5-frequency".into(),
            deps,
            overhead,
            compute,
            work: Some(work),
            output_bytes: 0,
        });
    }

    // Overlap tasks are sinks too; keep only `frequency` as the checked
    // sink by adding a tiny join task? No: multiple sinks are fine — the
    // report returns all of them.
    Pipeline::new(tasks)
}

/// Run the workflow end-to-end under a mode; returns the run report plus
/// the decoded frequency table (for correctness checks).
pub fn run(
    cfg: &GenomesConfig,
    mode: DataMode,
) -> Result<(RunReport, BTreeMap<u32, u32>)> {
    let pipeline = build_pipeline(cfg)?;
    let n = pipeline.tasks.len();
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: n,
        submit_overhead: Duration::from_millis(2),
        ..Default::default()
    }));
    let store = Store::memory("genomes");
    let report = pipeline.run(&cluster, &store, mode)?;
    let freq_bytes = report
        .sink_outputs
        .iter()
        .rev()
        .find(|(i, _)| pipeline.tasks[*i].stage == "5-frequency")
        .map(|(_, b)| b.clone())
        .expect("frequency sink present");
    let freq = BTreeMap::<u32, u32>::from_bytes(&freq_bytes)?;
    Ok((report, freq))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> GenomesConfig {
        GenomesConfig {
            individuals: 12,
            snps_per_chunk: 200,
            chunks: 3,
            groups: 2,
            task_overhead: Duration::from_millis(10),
            compute_floor: Duration::from_millis(5),
            seed: 7,
        }
    }

    #[test]
    fn dataset_is_deterministic_and_sparse() {
        let cfg = tiny();
        let a = generate_dataset(&cfg);
        let b = generate_dataset(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        let nonzero: usize = a[0].genotypes.iter().filter(|&&g| g > 0).count();
        let total = a[0].genotypes.len();
        assert!(nonzero > 0 && nonzero < total / 4, "{nonzero}/{total}");
    }

    #[test]
    fn stage_functions_consistent() {
        let cfg = tiny();
        let ds = generate_dataset(&cfg);
        let per_ind = extract_individuals(&ds[0]);
        assert_eq!(per_ind.len(), cfg.individuals);
        // Every reported variant is indeed nonzero in the matrix.
        for (ind, vars) in per_ind.iter().enumerate() {
            for &v in vars {
                let row = (v - ds[0].snp_offset) as usize;
                assert!(ds[0].genotypes[row * cfg.individuals + ind] > 0);
            }
        }
        let sel = sift_select(&ds[0], 0.3);
        assert!(!sel.is_empty());
        assert!(sel.len() < cfg.snps_per_chunk);
        // Threshold monotonicity.
        assert!(sift_select(&ds[0], 0.9).len() >= sel.len());
        assert_eq!(sift_select(&ds[0], 0.0).len(), 0);
    }

    #[test]
    fn reference_run_is_nonempty() {
        let freq = run_reference(&tiny());
        assert!(!freq.is_empty());
        assert!(freq.values().all(|&c| c >= 2));
    }

    #[test]
    fn pipeline_matches_reference_all_modes() {
        let cfg = tiny();
        let want = run_reference(&cfg);
        for mode in
            [DataMode::NoProxy, DataMode::Proxy, DataMode::ProxyFuture]
        {
            let (_report, freq) = run(&cfg, mode).unwrap();
            assert_eq!(freq, want, "{mode:?}");
        }
    }

    #[test]
    fn proxyfuture_reduces_makespan() {
        let cfg = GenomesConfig {
            task_overhead: Duration::from_millis(50),
            compute_floor: Duration::from_millis(25),
            ..tiny()
        };
        let (base, _) = run(&cfg, DataMode::NoProxy).unwrap();
        let (pf, _) = run(&cfg, DataMode::ProxyFuture).unwrap();
        assert!(
            pf.makespan < base.makespan,
            "ProxyFuture {:.3}s !< baseline {:.3}s",
            pf.makespan,
            base.makespan
        );
    }

    #[test]
    fn stage_envelopes_overlap_under_proxyfuture() {
        let cfg = tiny();
        let (report, _) = run(&cfg, DataMode::ProxyFuture).unwrap();
        let s1 = report.timeline.stage_envelope("compute");
        assert!(s1.is_some());
        // Stage-level rendering works through task name prefixes.
        let recs = report.timeline.records();
        assert!(recs.iter().any(|r| r.task.starts_with("individuals-")));
        assert!(recs.iter().any(|r| r.task == "frequency"));
    }
}
