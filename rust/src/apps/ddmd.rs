//! DeepDriveMD-style ML-guided molecular dynamics (paper Sec II & VI,
//! Fig 9).
//!
//! The paper's deployment couples MD simulations with an ML model:
//! simulation frames are featurized into contact maps, an autoencoder
//! embeds them, and inference latency gates how fast new simulations can
//! be steered. Two inference architectures are compared:
//!
//! * **baseline** — each inference batch is a fresh engine task: pay task
//!   submission, *model load* (the paper measured 100 ms – 2 s library/
//!   model import), and result transfer through the client, every time;
//! * **ProxyStream** — one *persistent inference actor* consumes batch
//!   proxies from a stream, keeps the model warm, publishes results
//!   through ProxyFutures, and receives new model weights via a
//!   ProxyFuture-announced update channel.
//!
//! The autoencoder is the real L2/L1 artifact: `encode_b{1,8,32}` compiled
//! from JAX+Pallas and executed via PJRT ([`crate::runtime`]). Python is
//! not involved at any point in this module.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::broker::BrokerState;
use crate::codec::{Decode, Encode, F32s, Reader};
use crate::engine::{ClusterConfig, LocalCluster};
use crate::error::{Error, Result};
use crate::futures::ProxyFuture;
use crate::netsim::{profiles, spin_sleep};
use crate::rng::Rng;
use crate::runtime::ModelRegistry;
use crate::store::Store;
use crate::stream::{
    EmbeddedLogPublisher, EmbeddedLogSubscriber, Metadata, StreamConsumer,
    StreamProducer,
};

/// Workload knobs.
#[derive(Debug, Clone)]
pub struct DdmdConfig {
    /// Inference rounds (one batch per round).
    pub rounds: usize,
    /// First batch size; grows linearly like the paper's accumulating
    /// data pool.
    pub initial_batch: usize,
    /// Batch growth per round (capped at the largest compiled batch).
    pub batch_growth: usize,
    /// Baseline-only: per-task model load cost.
    pub model_load: Duration,
    /// Baseline-only: engine submission overhead per task.
    pub submit_overhead: Duration,
    /// Run the trainer thread (ProxyStream mode) and swap models.
    pub train: bool,
    pub seed: u64,
}

impl Default for DdmdConfig {
    fn default() -> Self {
        DdmdConfig {
            rounds: 10,
            initial_batch: 4,
            batch_growth: 2,
            model_load: Duration::from_millis(150),
            submit_overhead: Duration::from_millis(5),
            train: true,
            seed: 42,
        }
    }
}

/// One inference round's measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundStat {
    pub round: usize,
    pub batch: usize,
    /// Round-trip time: batch ready → latents received (seconds).
    pub rtt: f64,
}

/// Result of a run.
#[derive(Debug, Clone)]
pub struct DdmdReport {
    pub rounds: Vec<RoundStat>,
    pub mean_rtt: f64,
    /// Latent-vector checksum for cross-mode correctness comparison.
    pub checksum: f64,
    /// Model updates applied (ProxyStream mode).
    pub model_updates: usize,
}

fn summarize(rounds: Vec<RoundStat>, checksum: f64, updates: usize) -> DdmdReport {
    let mean_rtt = if rounds.is_empty() {
        0.0
    } else {
        rounds.iter().map(|r| r.rtt).sum::<f64>() / rounds.len() as f64
    };
    DdmdReport { rounds, mean_rtt, checksum, model_updates: updates }
}

/// Generate one synthetic MD frame (a folded-ish random walk) and
/// featurize it through the PJRT `featurize_b1` artifact.
pub fn simulate_frame(
    reg: &ModelRegistry,
    rng: &mut Rng,
) -> Result<Vec<f32>> {
    let n = reg.geometry("n_residues").unwrap_or(32) as usize;
    let mut coords = Vec::with_capacity(n * 3);
    let (mut x, mut y, mut z) = (0.0f32, 0.0f32, 0.0f32);
    for _ in 0..n {
        x += rng.normal() as f32 * 2.0;
        y += rng.normal() as f32 * 2.0;
        z += rng.normal() as f32 * 2.0;
        coords.extend_from_slice(&[x, y, z]);
    }
    let out = reg.execute_with_bank("featurize_b1", &[("coords", &coords)])?;
    Ok(out.into_iter().next().expect("features"))
}

/// Pick the smallest compiled encode batch ≥ `b` and run inference,
/// padding with zero rows and truncating the output back to `b` rows.
pub fn encode_batch(
    reg: &ModelRegistry,
    params: Option<&EncoderParams>,
    batch: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>> {
    const COMPILED: [usize; 3] = [1, 8, 32];
    let b = batch.len();
    let d = reg.geometry("feature_dim").unwrap_or(1024) as usize;
    let l = reg.geometry("latent_dim").unwrap_or(32) as usize;
    let bucket = *COMPILED
        .iter()
        .find(|&&c| c >= b)
        .ok_or_else(|| Error::Config(format!("batch {b} exceeds max 32")))?;
    let mut x = vec![0.0f32; bucket * d];
    for (i, row) in batch.iter().enumerate() {
        if row.len() != d {
            return Err(Error::Config(format!(
                "feature row {i} has {} elems, want {d}",
                row.len()
            )));
        }
        x[i * d..(i + 1) * d].copy_from_slice(row);
    }
    let name = format!("encode_b{bucket}");
    let out = match params {
        Some(p) => reg.execute_f32(
            &name,
            &[&p.w1, &p.b1, &p.w2, &p.b2, &x],
        )?,
        None => reg.execute_with_bank(&name, &[("x", &x)])?,
    };
    let z = &out[0];
    Ok((0..b).map(|i| z[i * l..(i + 1) * l].to_vec()).collect())
}

/// Encoder weights (the model artifact shipped to the inference actor).
#[derive(Debug, Clone, PartialEq)]
pub struct EncoderParams {
    pub version: u64,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl Encode for EncoderParams {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.version.encode(buf);
        F32s(self.w1.clone()).encode(buf);
        F32s(self.b1.clone()).encode(buf);
        F32s(self.w2.clone()).encode(buf);
        F32s(self.b2.clone()).encode(buf);
    }
}

impl Decode for EncoderParams {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(EncoderParams {
            version: Decode::decode(r)?,
            w1: F32s::decode(r)?.0,
            b1: F32s::decode(r)?.0,
            w2: F32s::decode(r)?.0,
            b2: F32s::decode(r)?.0,
        })
    }
}

impl EncoderParams {
    pub fn from_bank(reg: &ModelRegistry) -> Result<EncoderParams> {
        let bank = reg.initial_params()?;
        let get = |k: &str| -> Result<Vec<f32>> {
            bank.get(k)
                .cloned()
                .ok_or_else(|| Error::Runtime(format!("missing param {k}")))
        };
        Ok(EncoderParams {
            version: 0,
            w1: get("w1")?,
            b1: get("b1")?,
            w2: get("w2")?,
            b2: get("b2")?,
        })
    }
}

/// Pre-generate the feature pool the rounds draw from (isolates the
/// measured inference path from simulation cost, as the paper's Fig 9
/// isolates inference round-trips).
pub fn feature_pool(
    reg: &ModelRegistry,
    n: usize,
    seed: u64,
) -> Result<Vec<Vec<f32>>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| simulate_frame(reg, &mut rng)).collect()
}

fn batch_sizes(cfg: &DdmdConfig) -> Vec<usize> {
    (0..cfg.rounds)
        .map(|r| (cfg.initial_batch + r * cfg.batch_growth).min(32))
        .collect()
}

fn checksum(latents: &[Vec<f32>]) -> f64 {
    latents
        .iter()
        .flat_map(|v| v.iter())
        .map(|&x| x as f64)
        .sum()
}

// --------------------------------------------------------------------------
// Baseline: task-per-batch through the engine.
// --------------------------------------------------------------------------

/// Baseline DeepDriveMD inference: one engine task per batch.
pub fn run_baseline(
    cfg: &DdmdConfig,
    reg: &Arc<ModelRegistry>,
) -> Result<DdmdReport> {
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: 1, // one inference GPU in the paper's deployment
        submit_overhead: cfg.submit_overhead,
        submit_link: Some(Arc::new(profiles::client_nic())),
        result_link: Some(Arc::new(profiles::client_nic())),
        models: Some(reg.clone()),
    }));
    let sizes = batch_sizes(cfg);
    let pool = feature_pool(reg, *sizes.iter().max().unwrap_or(&1), cfg.seed)?;
    let model_load = cfg.model_load;

    let mut rounds = Vec::new();
    let mut sum = 0.0;
    for (round, &b) in sizes.iter().enumerate() {
        let batch: Vec<Vec<f32>> = pool[..b].to_vec();
        let payload = batch
            .iter()
            .map(|v| F32s(v.clone()))
            .collect::<Vec<_>>()
            .to_bytes();
        let t0 = Instant::now();
        let fut = cluster.submit(
            Box::new(move |ctx, payload| {
                // Fresh task: model "loads" every time.
                spin_sleep(model_load);
                let reg = ctx
                    .models
                    .as_ref()
                    .ok_or_else(|| Error::Config("no models".into()))?;
                let batch: Vec<F32s> = Vec::from_bytes(&payload)?;
                let rows: Vec<Vec<f32>> =
                    batch.into_iter().map(|f| f.0).collect();
                let latents = encode_batch(reg, None, &rows)?;
                Ok(latents
                    .into_iter()
                    .map(F32s)
                    .collect::<Vec<_>>()
                    .to_bytes())
            }),
            payload,
        );
        let result = fut.wait()?;
        let latents: Vec<F32s> = Vec::from_bytes(&result)?;
        sum += checksum(
            &latents.iter().map(|f| f.0.clone()).collect::<Vec<_>>(),
        );
        rounds.push(RoundStat {
            round,
            batch: b,
            rtt: t0.elapsed().as_secs_f64(),
        });
    }
    Ok(summarize(rounds, sum, 0))
}

// --------------------------------------------------------------------------
// ProxyStream: persistent inference actor.
// --------------------------------------------------------------------------

/// Wire format for one inference request: proxy the batch, carry the
/// result-future in the event metadata (as hex-encoded factory bytes).
fn encode_hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn decode_hex(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(Error::Codec("odd hex length".into()));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|e| Error::Codec(format!("bad hex: {e}")))
        })
        .collect()
}

/// ProxyStream DeepDriveMD inference: persistent actor + streamed batches.
pub fn run_proxystream(
    cfg: &DdmdConfig,
    reg: &Arc<ModelRegistry>,
) -> Result<DdmdReport> {
    let broker = BrokerState::new();
    let store = Store::memory("ddmd");
    // Bulk data takes the same NIC the baseline paid, for a fair compare.
    let link = Arc::new(profiles::client_nic());

    // Model-update channel: trainer → actor.
    let model_topic = "model-updates";

    // Inference actor: consumes batch events, keeps the model warm.
    let actor_reg = reg.clone();
    let actor_broker = broker.clone();
    let stop = Arc::new(AtomicBool::new(false));
    let actor_stop = stop.clone();
    let updates = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let actor_updates = updates.clone();
    let actor: std::thread::JoinHandle<Result<()>> =
        std::thread::Builder::new()
            .name("inference-actor".into())
            .spawn(move || {
                let mut consumer = StreamConsumer::new(
                    EmbeddedLogSubscriber::new(actor_broker.clone(), "batches"),
                );
                let mut model_sub =
                    EmbeddedLogSubscriber::new(actor_broker, model_topic);
                // Load the model ONCE (the persistent-actor payoff).
                let mut params = EncoderParams::from_bank(&actor_reg)?;
                loop {
                    // Non-blocking check for a new model announcement.
                    use crate::stream::Subscriber as _;
                    if let Some(ev) =
                        model_sub.next_event(Some(Duration::ZERO))?
                    {
                        if let Some(factory) = ev.factory {
                            let p: crate::proxy::Proxy<EncoderParams> =
                                crate::proxy::Proxy::from_factory(factory);
                            params = p.into_inner()?;
                            actor_updates.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    let next = consumer
                        .next_proxy::<Vec<F32s>>(Some(Duration::from_millis(50)));
                    let (proxy, md) = match next {
                        Ok(Some(x)) => x,
                        Ok(None) => return Ok(()), // stream closed
                        Err(Error::Timeout(..)) => {
                            if actor_stop.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                            continue;
                        }
                        Err(e) => return Err(e),
                    };
                    // Resolve = bulk transfer store → actor (modelled NIC).
                    let batch = proxy.into_inner()?;
                    let rows: Vec<Vec<f32>> =
                        batch.into_iter().map(|f| f.0).collect();
                    let latents = encode_batch(&actor_reg, Some(&params), &rows)?;
                    // Publish the result through the caller's future.
                    let fut_bytes = decode_hex(
                        md.get("result-future")
                            .ok_or_else(|| {
                                Error::Protocol("missing result-future".into())
                            })?,
                    )?;
                    let fut: ProxyFuture<Vec<F32s>> =
                        ProxyFuture::from_bytes(&fut_bytes)?;
                    fut.set_result(
                        &latents.into_iter().map(F32s).collect::<Vec<_>>(),
                    )?;
                }
            })
            .expect("spawn inference-actor");

    // Trainer thread: periodically publishes refreshed weights (running
    // the real train_step artifact), announced via the model topic.
    let trainer: Option<std::thread::JoinHandle<Result<()>>> = if cfg.train {
        let treg = reg.clone();
        let tbroker = broker.clone();
        let tstore = store.clone();
        let tstop = stop.clone();
        let seed = cfg.seed;
        Some(
            std::thread::Builder::new()
                .name("trainer".into())
                .spawn(move || {
                    let d = treg.geometry("feature_dim").unwrap_or(1024)
                        as usize;
                    let b = treg.geometry("train_batch").unwrap_or(32)
                        as usize;
                    let mut params = treg.params_in_order()?;
                    let mut rng = Rng::new(seed ^ 0x7A11);
                    let mut producer = StreamProducer::new(
                        EmbeddedLogPublisher::new(tbroker),
                        Some(tstore),
                    );
                    let mut version = 0u64;
                    while !tstop.load(Ordering::Relaxed) {
                        let x: Vec<f32> =
                            (0..b * d).map(|_| rng.f32()).collect();
                        let lr = [0.01f32];
                        let mut inputs: Vec<&[f32]> =
                            params.iter().map(|p| p.as_slice()).collect();
                        inputs.push(&x);
                        inputs.push(&lr);
                        let mut out =
                            treg.execute_f32("train_step_b32", &inputs)?;
                        out.pop(); // loss
                        params = out;
                        version += 1;
                        let update = EncoderParams {
                            version,
                            w1: params[0].clone(),
                            b1: params[1].clone(),
                            w2: params[2].clone(),
                            b2: params[3].clone(),
                        };
                        producer.send(
                            model_topic,
                            &update,
                            Metadata::new(),
                        )?;
                        std::thread::sleep(Duration::from_millis(100));
                    }
                    Ok(())
                })
                .expect("spawn trainer"),
        )
    } else {
        None
    };

    // Client: stream batches, await result futures.
    let mut producer = StreamProducer::new(
        EmbeddedLogPublisher::new(broker.clone()),
        Some(store.clone()),
    );
    let sizes = batch_sizes(cfg);
    let pool = feature_pool(reg, *sizes.iter().max().unwrap_or(&1), cfg.seed)?;
    let mut rounds = Vec::new();
    let mut sum = 0.0;
    for (round, &b) in sizes.iter().enumerate() {
        let batch: Vec<F32s> =
            pool[..b].iter().map(|v| F32s(v.clone())).collect();
        let result_future: ProxyFuture<Vec<F32s>> = store.future();
        let mut md = Metadata::new();
        md.insert(
            "result-future".into(),
            encode_hex(&result_future.to_bytes()),
        );
        let t0 = Instant::now();
        // Bulk put models the producer→store hop on the shared NIC.
        link.transfer(batch.iter().map(|f| f.0.len() * 4).sum());
        producer.send("batches", &batch, md)?;
        let latents =
            result_future.result(Some(Duration::from_secs(60)))?;
        sum += checksum(
            &latents.iter().map(|f| f.0.clone()).collect::<Vec<_>>(),
        );
        rounds.push(RoundStat {
            round,
            batch: b,
            rtt: t0.elapsed().as_secs_f64(),
        });
    }

    // When training, linger until at least one model update lands so the
    // update path is always exercised (the trainer's first step includes
    // a one-time executable compile).
    if cfg.train {
        let deadline = Instant::now() + Duration::from_secs(20);
        while updates.load(Ordering::Relaxed) == 0 && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    producer.close_topic("batches")?;
    stop.store(true, Ordering::Relaxed);
    actor.join().map_err(|_| Error::Task("actor panicked".into()))??;
    if let Some(t) = trainer {
        t.join().map_err(|_| Error::Task("trainer panicked".into()))??;
    }
    Ok(summarize(
        rounds,
        sum,
        updates.load(Ordering::Relaxed),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::load(default_artifacts_dir()).unwrap()
    }

    fn quick() -> DdmdConfig {
        DdmdConfig {
            rounds: 4,
            initial_batch: 2,
            batch_growth: 3,
            model_load: Duration::from_millis(60),
            submit_overhead: Duration::from_millis(3),
            train: false,
            seed: 9,
        }
    }

    #[test]
    fn simulate_frame_produces_valid_features() {
        let reg = registry();
        let mut rng = Rng::new(3);
        let f = simulate_frame(&reg, &mut rng).unwrap();
        assert_eq!(f.len(), 1024);
        assert!(f.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn encode_batch_buckets_and_truncates() {
        let reg = registry();
        let pool = feature_pool(&reg, 5, 1).unwrap();
        let z = encode_batch(&reg, None, &pool).unwrap();
        assert_eq!(z.len(), 5);
        assert_eq!(z[0].len(), 32);
        // Padding must not change the real rows: batch of 2 vs batch of 5
        // agree on shared rows.
        let z2 = encode_batch(&reg, None, &pool[..2]).unwrap();
        for (a, b) in z[..2].iter().zip(&z2) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn encoder_params_roundtrip() {
        let reg = registry();
        let p = EncoderParams::from_bank(&reg).unwrap();
        let back = EncoderParams::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn baseline_and_proxystream_agree_numerically() {
        let reg = registry();
        let cfg = quick();
        let base = run_baseline(&cfg, &reg).unwrap();
        let ps = run_proxystream(&cfg, &reg).unwrap();
        assert_eq!(base.rounds.len(), cfg.rounds);
        assert_eq!(ps.rounds.len(), cfg.rounds);
        assert!(
            (base.checksum - ps.checksum).abs()
                < 1e-3 * base.checksum.abs().max(1.0),
            "checksums diverge: {} vs {}",
            base.checksum,
            ps.checksum
        );
    }

    #[test]
    fn proxystream_cuts_mean_rtt() {
        let reg = registry();
        let cfg = DdmdConfig { rounds: 6, ..quick() };
        let base = run_baseline(&cfg, &reg).unwrap();
        let ps = run_proxystream(&cfg, &reg).unwrap();
        assert!(
            ps.mean_rtt < base.mean_rtt,
            "proxystream {:.4}s !< baseline {:.4}s",
            ps.mean_rtt,
            base.mean_rtt
        );
    }

    #[test]
    fn trainer_updates_reach_the_actor() {
        let reg = registry();
        let cfg = DdmdConfig {
            rounds: 8,
            train: true,
            ..quick()
        };
        let ps = run_proxystream(&cfg, &reg).unwrap();
        assert!(ps.model_updates > 0, "no model updates applied");
    }

    #[test]
    fn hex_roundtrip() {
        for v in [vec![], vec![0u8], vec![255, 0, 16, 32]] {
            assert_eq!(decode_hex(&encode_hex(&v)).unwrap(), v);
        }
        assert!(decode_hex("abc").is_err());
        assert!(decode_hex("zz").is_err());
    }
}
