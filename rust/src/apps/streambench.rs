//! Fig 6 scenario: scalable stream processing.
//!
//! Topology (paper Sec V-B): one producer publishes items of size `d` at
//! rate `r = (n-1)/s`; a dispatcher consumes the stream and launches an
//! `s`-second compute task per item on `n-1` workers. Three configurations:
//!
//! * [`StreamMode::PubSubInline`] — bulk data rides the event channel and
//!   passes *through* the dispatcher, which must receive, deserialize,
//!   re-serialize, and forward every payload (the paper's Redis Pub/Sub
//!   baseline, bottlenecked at the dispatcher NIC);
//! * [`StreamMode::StepStore`] — ADIOS2-like: the producer writes bulk
//!   data to a step-indexed store; the dispatcher forwards only the step
//!   index, and the *modified worker task code* reads the store directly;
//! * [`StreamMode::ProxyStream`] — our pattern: events carry proxy
//!   factories; the dispatcher forwards proxies untouched and workers
//!   resolve them, with no task-code changes.
//!
//! The dispatcher's NIC is a contended [`Link`] (transfers serialize), so
//! the Fig 6 collapse of the inline baseline at high `d·n` emerges from
//! the same mechanism as on the paper's testbed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::broker::{BrokerFabric, BrokerState};
use crate::codec::Bytes;
use crate::engine::{ClusterConfig, LocalCluster};
use crate::error::{Error, Result};
use crate::netsim::{spin_sleep, Link};
use crate::rng::Rng;
use crate::store::Store;
use crate::stream::{
    EmbeddedLogPublisher, EmbeddedLogSubscriber, Metadata,
    PartitionedLogPublisher, PartitionedLogSubscriber, Publisher,
    StreamConsumer, StreamProducer, Subscriber,
};

/// Streaming configuration under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamMode {
    PubSubInline,
    StepStore,
    ProxyStream,
}

impl StreamMode {
    pub fn label(&self) -> &'static str {
        match self {
            StreamMode::PubSubInline => "redis-pubsub",
            StreamMode::StepStore => "adios-like",
            StreamMode::ProxyStream => "proxystream",
        }
    }

    pub fn all() -> [StreamMode; 3] {
        [
            StreamMode::PubSubInline,
            StreamMode::StepStore,
            StreamMode::ProxyStream,
        ]
    }
}

/// Workload knobs.
#[derive(Debug, Clone)]
pub struct StreamBenchConfig {
    /// Total workers `n` (1 producer + dispatcher-side pool of `n-1`).
    pub workers: usize,
    /// Item size `d` in bytes.
    pub data_size: usize,
    /// Simulated compute time `s` per item.
    pub task_time: Duration,
    /// Items to push through the system.
    pub items: usize,
    /// Dispatcher NIC bandwidth (bytes/s); the paper's dispatcher
    /// processed ~100 MB/s including (de)serialization.
    pub dispatcher_bw: f64,
    /// Broker instances behind the event channel. 1 = the classic single
    /// embedded log; >1 = the partitioned broker fabric
    /// ([`crate::broker::fabric`]) with `4 * instances` topic partitions
    /// spread across the instances.
    pub broker_instances: usize,
    pub seed: u64,
}

impl Default for StreamBenchConfig {
    fn default() -> Self {
        StreamBenchConfig {
            workers: 8,
            data_size: 1_000_000,
            task_time: Duration::from_millis(200),
            items: 50,
            dispatcher_bw: 1.0e9,
            broker_instances: 1,
            seed: 6,
        }
    }
}

/// Result of one configuration run.
#[derive(Debug, Clone)]
pub struct StreamBenchReport {
    pub mode: StreamMode,
    pub tasks_per_sec: f64,
    pub elapsed: f64,
    pub items: usize,
    /// Payload checksum over all completed tasks (correctness signal).
    pub checksum: u64,
}

fn payload_checksum(data: &[u8]) -> u64 {
    // FNV-1a, cheap and deterministic.
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run the Fig 6 scenario under one mode.
pub fn run(cfg: &StreamBenchConfig, mode: StreamMode) -> Result<StreamBenchReport> {
    if cfg.workers < 2 {
        return Err(Error::Config("need ≥2 workers".into()));
    }
    let n_compute = cfg.workers - 1;
    // Event channel: one embedded log, or a partitioned fabric spreading
    // 4*N partitions over N instances (same stream semantics either way).
    let instances = cfg.broker_instances.max(1);
    let broker = BrokerState::new();
    let fabric = if instances > 1 {
        Some(BrokerFabric::embedded(instances, instances as u32 * 4)?.0)
    } else {
        None
    };
    let store = Store::memory("streambench");
    // Dispatcher NIC: contended — concurrent transfers queue.
    let dispatcher_nic =
        Arc::new(Link::new(Duration::from_micros(100), cfg.dispatcher_bw));
    // Store fabric: uncontended full-duplex (workers pull independently).
    let store_link = Arc::new(
        Link::new(Duration::from_micros(100), cfg.dispatcher_bw).uncontended(),
    );

    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: n_compute,
        ..Default::default()
    }));

    // Producer thread: fixed rate r = n_compute / s.
    let rate = n_compute as f64 / cfg.task_time.as_secs_f64();
    let interval = Duration::from_secs_f64(1.0 / rate);
    let producer_broker = broker.clone();
    let producer_store = store.clone();
    let items = cfg.items;
    let data_size = cfg.data_size;
    let seed = cfg.seed;
    let producer_fabric = fabric.clone();
    let producer = std::thread::Builder::new()
        .name("producer".into())
        .spawn(move || -> Result<u64> {
            let publisher: Box<dyn Publisher> = match producer_fabric {
                Some(f) => Box::new(PartitionedLogPublisher::new(f)),
                None => Box::new(EmbeddedLogPublisher::new(producer_broker)),
            };
            let mut producer =
                StreamProducer::new(publisher, Some(producer_store.clone()));
            let mut rng = Rng::new(seed);
            let mut sum = 0u64;
            let t0 = Instant::now();
            for i in 0..items {
                let data = rng.bytes(data_size);
                sum = sum.wrapping_add(payload_checksum(&data));
                let mut md = Metadata::new();
                md.insert("i".into(), i.to_string());
                match mode {
                    StreamMode::PubSubInline => {
                        producer.send_inline("t", &Bytes(data), md)?;
                    }
                    StreamMode::StepStore => {
                        // Write bulk under a step key, announce the step.
                        let key = format!("step-{i}");
                        producer_store.put_at(&key, &Bytes(data))?;
                        md.insert("step".into(), key);
                        producer.send_marker("t", md)?;
                    }
                    StreamMode::ProxyStream => {
                        producer.send("t", &Bytes(data), md)?;
                    }
                }
                // Rate limit.
                let target = t0 + interval * (i as u32 + 1);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
            }
            producer.close_topic("t")?;
            Ok(sum)
        })
        .expect("spawn producer");

    // Dispatcher (this thread): consume events, launch compute tasks.
    let subscriber: Box<dyn Subscriber> = match &fabric {
        Some(f) => Box::new(PartitionedLogSubscriber::new(f.clone(), "t", 0, 1)?),
        None => Box::new(EmbeddedLogSubscriber::new(broker.clone(), "t")),
    };
    let mut consumer = StreamConsumer::new(subscriber);
    let completed_sum = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut futs = Vec::with_capacity(cfg.items);
    let task_time = cfg.task_time;
    loop {
        let Some(event) =
            consumer.next_event(Some(Duration::from_secs(60)))?
        else {
            break; // end of stream
        };
        let sum = completed_sum.clone();
        let store_link = store_link.clone();
        let payload: Vec<u8>;
        let task: crate::engine::TaskFn = match mode {
            StreamMode::PubSubInline => {
                // Bulk bytes hit the dispatcher NIC (receive), get
                // deserialized, then re-serialized into the task payload
                // (send over the same NIC, contended).
                let inline =
                    event.inline.ok_or_else(|| {
                        Error::Protocol("inline event expected".into())
                    })?;
                dispatcher_nic.transfer(inline.0.len()); // broker→dispatcher
                let data: Bytes = // deserialize (copy)
                    crate::codec::Decode::from_bytes(&inline.0)?;
                payload = data.0; // re-serialize into the task payload (copy)
                dispatcher_nic.transfer(payload.len()); // dispatcher→worker
                Box::new(move |_ctx, payload| {
                    spin_sleep(task_time);
                    sum.fetch_add(
                        payload_checksum(&payload),
                        Ordering::Relaxed,
                    );
                    Ok(Vec::new())
                })
            }
            StreamMode::StepStore => {
                // Only the step key crosses the dispatcher.
                let key = event
                    .metadata
                    .get("step")
                    .ok_or_else(|| Error::Protocol("missing step".into()))?
                    .clone();
                let store = store.clone();
                payload = Vec::new();
                Box::new(move |_ctx, _| {
                    spin_sleep(task_time);
                    // Modified task code: read the store directly.
                    let data: Bytes = store
                        .get(&key)?
                        .ok_or_else(|| Error::NotFound(key.clone()))?;
                    store_link.transfer(data.0.len());
                    sum.fetch_add(payload_checksum(&data.0), Ordering::Relaxed);
                    store.evict(&key)?;
                    Ok(Vec::new())
                })
            }
            StreamMode::ProxyStream => {
                // The dispatcher forwards the ~100-byte factory untouched.
                let factory = event.factory.ok_or_else(|| {
                    Error::Protocol("factory event expected".into())
                })?;
                payload = crate::codec::Encode::to_bytes(&factory);
                let store = store.clone();
                Box::new(move |_ctx, payload| {
                    spin_sleep(task_time);
                    let factory =
                        <crate::proxy::Factory as crate::codec::Decode>::from_bytes(
                            &payload,
                        )?;
                    let p: crate::proxy::Proxy<Bytes> =
                        crate::proxy::Proxy::from_factory(factory.clone());
                    let data = p.into_inner()?;
                    store_link.transfer(data.0.len());
                    sum.fetch_add(payload_checksum(&data.0), Ordering::Relaxed);
                    store.evict(&factory.key)?;
                    Ok(Vec::new())
                })
            }
        };
        futs.push(cluster.submit(task, payload));
    }
    for f in &futs {
        f.wait()?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let produced_sum = producer
        .join()
        .map_err(|_| Error::Task("producer panicked".into()))??;
    let consumed_sum = completed_sum.load(Ordering::Relaxed);
    // Every payload must arrive intact regardless of path.
    let expected = {
        // producer accumulated with wrapping_add in order; tasks complete
        // out of order but addition is commutative over wrapping u64.
        produced_sum
    };
    if consumed_sum != expected {
        return Err(Error::Task(format!(
            "checksum mismatch: produced {expected:x}, consumed {consumed_sum:x}"
        )));
    }
    Ok(StreamBenchReport {
        mode,
        tasks_per_sec: futs.len() as f64 / elapsed,
        elapsed,
        items: futs.len(),
        checksum: consumed_sum,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(mode: StreamMode) -> StreamBenchReport {
        run(
            &StreamBenchConfig {
                workers: 4,
                data_size: 200_000,
                task_time: Duration::from_millis(50),
                items: 12,
                dispatcher_bw: 1.0e9,
                broker_instances: 1,
                seed: 5,
            },
            mode,
        )
        .unwrap()
    }

    #[test]
    fn all_modes_complete_all_items_with_matching_checksums() {
        let reports: Vec<_> = StreamMode::all().iter().map(|&m| quick(m)).collect();
        for r in &reports {
            assert_eq!(r.items, 12, "{:?}", r.mode);
            assert!(r.tasks_per_sec > 0.0);
        }
        // Same seed → same data → same checksum across modes.
        assert_eq!(reports[0].checksum, reports[1].checksum);
        assert_eq!(reports[1].checksum, reports[2].checksum);
    }

    #[test]
    fn proxystream_beats_inline_at_large_sizes() {
        let cfg = StreamBenchConfig {
            workers: 6,
            data_size: 4_000_000,
            task_time: Duration::from_millis(100),
            items: 20,
            dispatcher_bw: 5.0e7, // slow dispatcher NIC to expose the bottleneck
            broker_instances: 1,
            seed: 5,
        };
        let inline = run(&cfg, StreamMode::PubSubInline).unwrap();
        let proxy = run(&cfg, StreamMode::ProxyStream).unwrap();
        assert!(
            proxy.tasks_per_sec > inline.tasks_per_sec * 1.2,
            "proxystream {:.1}/s !>> inline {:.1}/s",
            proxy.tasks_per_sec,
            inline.tasks_per_sec
        );
    }

    #[test]
    fn rejects_single_worker() {
        let cfg = StreamBenchConfig { workers: 1, ..Default::default() };
        assert!(run(&cfg, StreamMode::ProxyStream).is_err());
    }

    #[test]
    fn partitioned_event_channel_matches_single_broker() {
        // Same workload over 1 embedded log vs a 4-instance fabric: every
        // item completes on both topologies with identical checksums.
        let base = StreamBenchConfig {
            workers: 4,
            data_size: 100_000,
            task_time: Duration::from_millis(30),
            items: 12,
            dispatcher_bw: 1.0e9,
            broker_instances: 1,
            seed: 9,
        };
        let single = run(&base, StreamMode::ProxyStream).unwrap();
        let sharded = run(
            &StreamBenchConfig { broker_instances: 4, ..base.clone() },
            StreamMode::ProxyStream,
        )
        .unwrap();
        assert_eq!(single.items, sharded.items);
        assert_eq!(single.checksum, sharded.checksum);
        // Inline mode pushes bulk through the partitioned brokers too.
        let inline = run(
            &StreamBenchConfig { broker_instances: 4, ..base },
            StreamMode::PubSubInline,
        )
        .unwrap();
        assert_eq!(inline.checksum, single.checksum);
    }
}
