//! Fig 7 scenario: memory management over a simulated map-reduce workflow.
//!
//! Paper Sec V-C: 8 consecutive map-reduces; each of 32 mappers receives
//! 100 MB and produces 10 MB; one reducer consumes all mapper outputs;
//! every task sleeps 5 s. Four configurations:
//!
//! * [`MemMode::NoProxy`]    — data rides the engine (Dask baseline);
//! * [`MemMode::Default`]    — proxies, never freed (ProxyStore default);
//! * [`MemMode::Manual`]     — proxies, freed by hand-written app logic
//!   with a-priori knowledge of last use;
//! * [`MemMode::Ownership`]  — owned/borrowed proxies, freed automatically.
//!
//! Measured: store-resident bytes over time (the paper's system-memory
//! trace), plus makespan. Sizes/durations are scaled ×1/10 by default.

use std::sync::Arc;
use std::time::Duration;

use crate::codec::{Bytes, Decode, Encode};
use crate::engine::{ClusterConfig, LocalCluster, StoreExecutor, TaskArg};
use crate::error::{Error, Result};
use crate::metrics::{MemorySampler, MemorySeries};
use crate::netsim::spin_sleep;
use crate::ownership::StoreOwnedExt;
use crate::rng::Rng;
use crate::store::Store;

/// Memory-management configuration under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemMode {
    NoProxy,
    Default,
    Manual,
    Ownership,
}

impl MemMode {
    pub fn label(&self) -> &'static str {
        match self {
            MemMode::NoProxy => "no-proxy",
            MemMode::Default => "proxy-default",
            MemMode::Manual => "proxy-manual",
            MemMode::Ownership => "proxy-ownership",
        }
    }

    pub fn all() -> [MemMode; 4] {
        [
            MemMode::NoProxy,
            MemMode::Default,
            MemMode::Manual,
            MemMode::Ownership,
        ]
    }
}

/// Workload knobs (defaults = paper's shape scaled ×1/10).
#[derive(Debug, Clone)]
pub struct MemBenchConfig {
    pub rounds: usize,
    pub mappers: usize,
    /// Bytes each mapper receives.
    pub map_input: usize,
    /// Bytes each mapper produces.
    pub map_output: usize,
    /// Per-task sleep.
    pub task_sleep: Duration,
    pub seed: u64,
}

impl Default for MemBenchConfig {
    fn default() -> Self {
        MemBenchConfig {
            rounds: 4,
            mappers: 8,
            map_input: 10_000_000,
            map_output: 1_000_000,
            task_sleep: Duration::from_millis(200),
            seed: 7,
        }
    }
}

/// One mode's result.
#[derive(Debug, Clone)]
pub struct MemBenchReport {
    pub mode: MemMode,
    pub series: MemorySeries,
    pub makespan: f64,
    /// Reducer outputs checksum (correctness across modes).
    pub checksum: u64,
}

fn reduce_bytes(inputs: &[Vec<u8>]) -> Vec<u8> {
    // XOR-fold all mapper outputs into one block (order-insensitive).
    let len = inputs.iter().map(|v| v.len()).max().unwrap_or(0);
    let mut out = vec![0u8; len];
    for v in inputs {
        for (o, b) in out.iter_mut().zip(v) {
            *o ^= b;
        }
    }
    out
}

fn checksum64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Mapper: deterministic transform of its input slice.
fn map_work(input: &[u8], out_len: usize) -> Vec<u8> {
    let mut out = vec![0u8; out_len];
    for (i, o) in out.iter_mut().enumerate() {
        *o = input[i % input.len()].wrapping_mul(31).wrapping_add(i as u8);
    }
    out
}

/// Run the Fig 7 scenario in one mode.
pub fn run(cfg: &MemBenchConfig, mode: MemMode) -> Result<MemBenchReport> {
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: cfg.mappers.min(8),
        ..Default::default()
    }));
    let store = Store::memory(&format!("membench-{}", mode.label()));
    let executor = StoreExecutor::new(cluster.clone(), store.clone());
    let gauge = store.gauge().expect("memory connector has a gauge");
    let sampler =
        MemorySampler::start(Duration::from_millis(20), vec![gauge]);
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let sleep = cfg.task_sleep;
    let mut final_checksum = 0u64;

    for _round in 0..cfg.rounds {
        // Client materializes each mapper's input (the paper's generator).
        let inputs: Vec<Vec<u8>> =
            (0..cfg.mappers).map(|_| rng.bytes(cfg.map_input)).collect();

        let out_len = cfg.map_output;
        let map_futs: Vec<_> = match mode {
            MemMode::NoProxy => inputs
                .iter()
                .map(|inp| {
                    // Data rides the engine payload.
                    cluster.submit(
                        Box::new(move |_ctx, payload| {
                            spin_sleep(sleep);
                            Ok(map_work(&payload, out_len))
                        }),
                        inp.clone(),
                    )
                })
                .collect(),
            _ => inputs
                .iter()
                .map(|inp|

 {
                    // Proxy path, with mode-specific management below.
                    let arg = match mode {
                        MemMode::Ownership => {
                            let owned =
                                store.owned_proxy(&Bytes(inp.clone()))?;
                            // Transfer ownership: the mapper is the last
                            // consumer of its input.
                            Ok::<TaskArg, Error>(
                                executor.make_owned_transfer(owned),
                            )
                        }
                        _ => {
                            let p = store.proxy(&Bytes(inp.clone()))?;
                            Ok(TaskArg::Proxied(Bytes(p.to_bytes())))
                        }
                    }?;
                    let manual = mode == MemMode::Manual;
                    let fut = executor.submit::<Bytes>(
                        vec![arg],
                        Box::new(move |_ctx, args| {
                            spin_sleep(sleep);
                            let data: Bytes = match &args[0] {
                                TaskArg::OwnedTransfer(_) => {
                                    let owned =
                                        args[0].take_owned::<Bytes>()?;
                                    let v = owned.resolve()?.clone();
                                    v // owned drops → input evicted
                                }
                                other => {
                                    let v: Bytes = other.get()?;
                                    if manual {
                                        // Hand-written free: the app knows
                                        // this was the last read.
                                        if let TaskArg::Proxied(b) = other {
                                            let p: crate::proxy::Proxy<Bytes> =
                                                crate::proxy::Proxy::from_bytes(&b.0)?;
                                            let f = p.factory();
                                            f.connector()?.evict(&f.key)?;
                                        }
                                    }
                                    v
                                }
                            };
                            Ok(Bytes(map_work(&data.0, out_len)).to_bytes())
                        }),
                    );
                    Ok(fut)
                })
                .map(|r| r.map(|f| f.raw().clone()))
                .collect::<Result<Vec<_>>>()?,
        };

        // Reducer consumes all mapper outputs.
        let mapper_outputs: Vec<Vec<u8>> = match mode {
            MemMode::NoProxy => map_futs
                .iter()
                .map(|f| f.wait())
                .collect::<Result<_>>()?,
            _ => map_futs
                .iter()
                .map(|f| {
                    let raw = f.wait()?;
                    let arg = TaskArg::from_bytes(&raw)?;
                    match (&arg, mode) {
                        (TaskArg::Proxied(b), MemMode::Manual | MemMode::Ownership) => {
                            // Consume-once: resolve then evict.
                            let p: crate::proxy::Proxy<Bytes> =
                                crate::proxy::Proxy::from_bytes(&b.0)?;
                            let factory = p.factory().clone();
                            let v = p.into_inner()?;
                            factory.connector()?.evict(&factory.key)?;
                            Ok(v.0)
                        }
                        _ => arg.get::<Bytes>().map(|b| b.0),
                    }
                })
                .collect::<Result<_>>()?,
        };
        let reduced = {
            let rf = cluster.submit(
                Box::new(move |_ctx, payload| {
                    spin_sleep(sleep);
                    let parts: Vec<Bytes> = Vec::from_bytes(&payload)?;
                    let inputs: Vec<Vec<u8>> =
                        parts.into_iter().map(|b| b.0).collect();
                    Ok(reduce_bytes(&inputs))
                }),
                mapper_outputs
                    .iter()
                    .map(|v| Bytes(v.clone()))
                    .collect::<Vec<_>>()
                    .to_bytes(),
            );
            rf.wait()?
        };
        final_checksum ^= checksum64(&reduced);
    }

    let makespan = t0.elapsed().as_secs_f64();
    // Give deferred releases (executor callbacks) a beat before the final
    // sample.
    std::thread::sleep(Duration::from_millis(60));
    let series = sampler.stop();
    Ok(MemBenchReport { mode, series, makespan, checksum: final_checksum })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> MemBenchConfig {
        MemBenchConfig {
            rounds: 2,
            mappers: 4,
            map_input: 500_000,
            map_output: 50_000,
            task_sleep: Duration::from_millis(30),
            seed: 11,
        }
    }

    #[test]
    fn all_modes_same_result() {
        let cfg = quick();
        let reports: Vec<_> = MemMode::all()
            .iter()
            .map(|&m| run(&cfg, m).unwrap())
            .collect();
        for w in reports.windows(2) {
            assert_eq!(
                w[0].checksum, w[1].checksum,
                "{:?} vs {:?}",
                w[0].mode, w[1].mode
            );
        }
    }

    #[test]
    fn default_mode_grows_ownership_flat() {
        let cfg = quick();
        let default = run(&cfg, MemMode::Default).unwrap();
        let owned = run(&cfg, MemMode::Ownership).unwrap();
        let manual = run(&cfg, MemMode::Manual).unwrap();
        // Default leaks every input+output; final resident ≈ everything.
        assert!(
            default.series.final_store()
                > (cfg.rounds * cfg.mappers * cfg.map_input / 2) as i64,
            "default final {} too small",
            default.series.final_store()
        );
        // Ownership and manual end (near) empty.
        assert!(
            owned.series.final_store() < cfg.map_input as i64,
            "ownership final {}",
            owned.series.final_store()
        );
        assert!(
            manual.series.final_store() < cfg.map_input as i64,
            "manual final {}",
            manual.series.final_store()
        );
        // Ownership tracks manual (the paper's headline for Fig 7).
        let ratio = owned.series.mean_store().max(1.0)
            / manual.series.mean_store().max(1.0);
        assert!(
            (0.5..2.0).contains(&ratio),
            "ownership mean {} vs manual mean {}",
            owned.series.mean_store(),
            manual.series.mean_store()
        );
    }

    #[test]
    fn no_proxy_keeps_store_empty() {
        let r = run(&quick(), MemMode::NoProxy).unwrap();
        assert_eq!(r.series.peak_store(), 0);
        assert!(r.makespan > 0.0);
    }
}
