//! The paper's three motivating applications (Sec II / VI), rebuilt on the
//! proxystore stack:
//!
//! * [`genomes`] — the 1000 Genomes mutational-overlap workflow (Fig 8),
//!   on a synthetic genotype dataset with the same five-stage data flow;
//! * [`ddmd`] — DeepDriveMD-style ML-guided molecular dynamics (Fig 9):
//!   simulation → featurize → inference → train, with the autoencoder
//!   executing as a PJRT artifact (JAX + Pallas, AOT);
//! * [`mof`] — MOF Generation (Fig 10): a thinker steering generate/
//!   assemble/score tasks, with proxy lifetimes managed by the ownership
//!   model.

pub mod ddmd;
pub mod genomes;
pub mod membench;
pub mod mof;
pub mod streambench;
