//! # ProxyStore-RS
//!
//! A Rust + JAX + Pallas reproduction of *"Object Proxy Patterns for
//! Accelerating Distributed Applications"* (Pauloski et al., 2024): the
//! transparent lazy object proxy plus the paper's three high-level
//! patterns —
//!
//! * **ProxyFutures** ([`futures`]) — engine-agnostic distributed futures
//!   whose proxies can be minted before the value exists;
//! * **ProxyStream** ([`stream`]) — object streaming that decouples event
//!   metadata from bulk data;
//! * **Ownership** ([`ownership`]) — Rust-style owned/borrowed proxies
//!   with automatic distributed eviction, plus coarse lifetimes.
//!
//! Everything the patterns depend on is built in-tree: a binary codec
//! ([`codec`]), a Redis-like KV server ([`kv`]), a Kafka-like broker
//! ([`broker`]), connectors and the typed [`store`], a Dask-like task
//! execution engine ([`engine`]), a network simulator ([`netsim`]), and a
//! PJRT runtime ([`runtime`]) that executes the JAX/Pallas-compiled
//! artifacts from `artifacts/` on the request path with no Python.
//!
//! Scaling beyond a single mediated channel is the job of the **sharded
//! store fabric** ([`shard`]): a consistent-hash ring with virtual nodes
//! routes keys across N backend connectors with per-key replication and
//! read-fallback, the KV wire protocol pipelines batched
//! `MGET`/`MPUT`/`MDEL`/`MEXISTS` traffic, and the [`store`] surfaces
//! batched `put_many`/`get_many` plus proxy batch-prefetch
//! ([`proxy::prefetch`]) so streaming consumers amortize round trips. A
//! proxy minted against the fabric stays fully self-contained: its
//! factory carries the serialized shard layout. The fabric is also
//! **elastic** ([`shard::rebalance`]): shards can be added and removed at
//! runtime, with a background migration daemon moving only the ~1/N
//! remapped keys while reads serve through both the old and new placement
//! — no client ever observes a missing key during a rebalance.
//!
//! The event channel scales the same way: the **partitioned broker
//! fabric** ([`broker::fabric`]) spreads a topic's partitions across N
//! broker instances with the same ring, preserving per-partition order
//! while produce/fetch throughput grows with the instance count.
//!
//! The client-side data plane is **submission-based** ([`ops`]): a typed
//! [`Op`](ops::Op) names one connector operation, a
//! [`Pending`](ops::Pending) is the condvar-backed completion handle, and
//! [`Connector::submit`](store::Connector::submit) turns any channel into
//! a nonblocking endpoint. The TCP KV client pipelines submitted ops on
//! one socket (a reader thread matches FIFO responses to handles), a
//! shared fixed-size reactor pool ([`ops::reactor`]) drives blocking
//! bridges and every fan-out without per-call thread spawns, and the
//! [`store`] surfaces it as `put_async`/`get_async`/`proxy_async` so
//! resolution overlaps with compute.
//!
//! Waiting is **event-driven**: every blocking rendezvous — ProxyFuture
//! resolution, `wait_get`, fan-in joins — rides the out-of-band
//! watch/notify plane ([`store::Connector::watch`]). A waiter arms a
//! watch (a registry callback in-process, a `Watch`/`Notify` push pair on
//! the pipelined TCP wire, replica arms racing on the shard fabrics that
//! re-arm across elastic epoch flips) and parks on the handle: a parked
//! waiter costs no poll tick, no dedicated connection, and no pool
//! worker, and a single put wakes exactly its key's waiters in one push.
//! [`futures::when_all`]/[`futures::when_any`] compose watch handles into
//! joins that park once over N keys.
//!
//! # Server ingress
//!
//! Both servers are spawned through one [`net::ServerBuilder`] and offer
//! two ingress modes ([`net::Ingress`]):
//!
//! * **`EventLoop`** (default on Linux) — a small pool of epoll reactor
//!   threads multiplexes every connection: nonblocking sockets,
//!   incremental frame reassembly across partial reads, and coalesced
//!   writes flushed once per readiness burst. Blocking ops (`WaitGet`,
//!   `BRPop`, broker long-poll fetches) *probe* the engine first and
//!   defer only true waits to short-lived helper threads, and watch
//!   `Notify` frames are injected into the owning loop from whichever
//!   thread stores the key — 10k+ connections cost a bounded thread
//!   set.
//! * **`Threaded`** — one blocking OS thread per connection; the
//!   portable fallback and the bench baseline.
//!
//! The pipelined KV client's wire behaviour is configurable through
//! [`kv::ClientOptions`]: pipeline window depth (backpressure on
//! in-flight ops), a write-coalescing flush policy (batch many small
//! frames into one flush), and connect/write timeouts — threaded
//! through [`store::TcpKvConnector`] descriptors so proxies round-trip
//! the tuning.
//!
//! # Zero-copy data plane
//!
//! Bulk value bytes cross the process without being copied. The unit of
//! sharing is [`codec::Buf`] — a cheaply clonable window (`Arc` +
//! offset/len) over an immutable byte allocation:
//!
//! * **Engine** — [`kv::KvState`] stores values as full-window `Buf`s,
//!   so a GET/MGET response, a watch `Notify`, the WAL append, and a
//!   snapshot all share the one stored allocation (refcount bumps, not
//!   copies).
//! * **Server egress** — [`kv::Response`] carries `Buf` payloads and
//!   encodes into a segmented [`net::WireFrame`]: header bytes are
//!   owned, payloads ride as shared segments. The epoll write path
//!   queues segments in a per-connection outbox and drains them with
//!   scatter-gather `writev`, so a 16 MiB reply costs one small header
//!   allocation and zero payload copies on the server.
//! * **Client ingress** — the pipelined client reads each response
//!   frame into one buffer and decodes *owned*
//!   ([`kv::decode_response_owned`]): values become `Buf` windows into
//!   that same buffer. [`kv::KvClient::get_view`] /
//!   [`store::Connector::get_view`] / [`store::Store::get_view`]
//!   surface the view; the owned `get` APIs flatten it for callers
//!   that need a `Vec`.
//!
//! Ownership rule: a `Buf` is immutable and outlives every clone of its
//! window — holding a view pins the whole backing allocation, so drop
//! views promptly when the value is a small slice of a large batch
//! frame. A copy is still taken where framing demands it: WAL records
//! (CRC framing re-encodes the record), the threaded ingress (flat
//! per-frame encode through a reused scratch buffer), sub-512 B shared
//! segments (inlined into the outbox — cheaper than an iovec entry; the
//! only outbox site counted in `data.bytes_copied`), and copy-mode
//! servers ([`net::ServerBuilder::zero_copy`]`(false)`, the bench
//! baseline). The `data.bytes_copied` / `data.value_bytes_{in,out}`
//! counters in `/metrics` make the difference measurable, and
//! `benches/zerocopy.rs` gates on it.
//!
//! *Migration note:* the former constructors
//! (`KvServer::spawn{,_with_state}`, `BrokerServer::spawn{,_with_state}`)
//! are deprecated shims; use `ServerBuilder::new().spawn_kv()` /
//! `.spawn_broker()`, or `.with_state(state).spawn()` to serve shared
//! state.
//!
//! # Observability
//!
//! Every fabric reports into one **telemetry plane**
//! ([`metrics::telemetry`]): a process-global registry of named counters,
//! gauges, and lock-free log-bucketed latency histograms, plus a bounded
//! ring of structured trace events. Instrumentation is always-on and
//! costs one atomic op per record ([`metrics::telemetry::set_enabled`]
//! turns it into a no-op); the per-store/per-fabric accessors
//! (`Store::metrics`, `ElasticShards::metrics`, shard router counters)
//! are exact local views mirrored into the same registry, so
//! [`metrics::telemetry::snapshot`] covers the whole process in one call:
//! KV client op latency and pipeline depth, KV server frame and notify
//! counts, per-shard router latency, migration progress, reactor queue
//! high-water, and watch-plane arm/fire/re-arm counts.
//!
//! Traces propagate **over the wire**: [`metrics::telemetry::start_trace`]
//! binds a trace to the current thread, the pipelined KV client wraps
//! each op in a `Request::Traced` envelope carrying `(trace_id, span_id)`,
//! and the server stamps a child span per op. Spans are **parent-linked
//! and timed** — each records `(trace_id, span_id, parent_span, start_us,
//! dur_us)` — so [`metrics::span_trees`] reassembles the cross-process
//! call tree (the client root span parenting every per-shard server
//! span) and [`metrics::chrome_trace_json`] exports it as Chrome
//! trace-viewer JSON, loadable in Perfetto or `chrome://tracing` with one
//! process row per node. Ops slower than
//! [`metrics::telemetry::set_slow_threshold`] (default 1ms) additionally
//! land in a bounded **slow-op log** with their trace/span ids and peer,
//! surviving trace-ring eviction.
//!
//! Snapshots are wire-encodable and **cluster-mergeable**:
//! `Request::Telemetry` (and the broker's `TelemetrySnap`) fetch a remote
//! process's registry, and [`metrics::ClusterSnapshot`] fans the scrape
//! across a whole fabric ([`metrics::ClusterSnapshot::scrape_sharded`],
//! `scrape_elastic`, `scrape_broker_fabric`) and merges the per-node
//! snapshots — histograms add bucket-wise, counters sum, gauges keep sum
//! and high-water — into one cluster view
//! ([`metrics::ClusterSnapshot::render`] — the CLI `obs` scenario).
//!
//! For pull-based monitoring, every server optionally serves an **HTTP
//! admin plane** on its epoll reactor
//! ([`net::ServerBuilder::admin_addr`], [`net::AdminService`]):
//! `curl :PORT/metrics` returns Prometheus text exposition (names
//! sanitized, labels escaped), `/healthz` and `/readyz` report liveness
//! and readiness (the elastic fabric flips `/readyz` to 503 while a
//! migration drains), `/conns` lists live connection counts and
//! registered probes, `/trace` serves the trace ring as Chrome JSON, and
//! `/slow` dumps the slow-op log. Text renderings
//! ([`metrics::TelemetrySnapshot::render`] — the CLI `stats` scenario)
//! and the per-bench dumps from [`benchlib`] remain for offline use.
//!
//! # Durability
//!
//! Both engines can serve **durably** from a data directory
//! ([`persist`]): point the builder at one and every acked mutation
//! survives a crash.
//!
//! ```no_run
//! use proxystore::net::ServerBuilder;
//! let server = ServerBuilder::new().data_dir("/var/lib/pallas").spawn_kv()?;
//! # Ok::<(), proxystore::Error>(())
//! ```
//!
//! The write path is a **segmented write-ahead log**
//! ([`persist::Wal`]): each mutation is encoded and appended under the
//! engine lock (so log order equals apply order), then group-committed —
//! concurrent committers coalesce onto one `fsync`, with the policy
//! ([`persist::FsyncPolicy`]) choosing between `EveryOp` (strongest:
//! every ack implies data on disk), `EveryN` (default, bounded loss
//! window, near-RAM throughput), and `Off` (rotation-only fsync).
//! Records are CRC-framed; replay stops at a torn tail (truncating it
//! physically, counted in `recovery.truncated_records`) and discards
//! anything after a corrupt record. Periodic **snapshots**
//! ([`persist::write_snapshot`]) bound replay time: a snapshot pins the
//! WAL horizon and closed segments at or below it are reclaimed.
//!
//! On disk, `<data_dir>/kv/{wal,snap}` holds the KV shard's log and
//! snapshots, and `<data_dir>/broker/topics/<hex(topic)>/p<N>/` holds one
//! log per partition — the WAL sequence number *is* the partition offset
//! — plus a committed-offsets checkpoint. Broker retention
//! ([`persist::DurabilityOptions::retain_segments`]/`retain_bytes`)
//! drops the oldest closed segments; recovery blanks the reclaimed
//! prefix so offsets stay dense.
//!
//! Recovery is automatic: reopening the same data dir loads the newest
//! valid snapshot, replays the WAL tail, and reports
//! [`RecoveryStats`](persist::RecoveryStats). A restarted shard rebinds
//! its old address ([`testing::fail::RestartableServer`] scripts this)
//! and [`shard::ElasticShards::rejoin_shard`] splices it back into a
//! live elastic fabric in place — same ring id, empty migration delta —
//! so reads never miss. Telemetry lands in the same registry
//! (`wal.appends`, `wal.fsync_us`, `snapshot.duration_us`,
//! `recovery.replayed_records`), visible in `/metrics`.

pub mod apps;
pub mod benchlib;
pub mod broker;
pub mod cli;
pub mod codec;
pub mod engine;
pub mod error;
pub mod futures;
pub mod kv;
pub mod metrics;
pub mod net;
pub mod netsim;
pub mod ops;
pub mod ownership;
pub mod persist;
pub mod proxy;
pub mod rng;
pub mod runtime;
pub mod shard;
pub mod store;
pub mod stream;
pub mod testing;
pub mod workflow;

pub use error::{Error, Result};

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Convenience prelude for examples and applications.
pub mod prelude {
    pub use crate::codec::{Buf, Bytes, Decode, Encode, F32s};
    pub use crate::error::{Error, Result};
    pub use crate::futures::{when_all, when_any, PendingResult, ProxyFuture};
    pub use crate::kv::{ClientOptions, FlushPolicy};
    pub use crate::metrics::{
        telemetry, ClusterSnapshot, TelemetrySnapshot, TraceCtx,
    };
    pub use crate::net::{Ingress, ServerBuilder};
    pub use crate::ops::{Op, OpResult, Pending};
    pub use crate::persist::{DurabilityOptions, FsyncPolicy};
    pub use crate::ownership::lifetime::StoreLifetimeExt;
    pub use crate::ownership::{
        borrow, clone_owned, into_owned, mut_borrow, update, ContextLifetime,
        LeaseLifetime, Lifetime, OwnedProxy, RefMutProxy, RefProxy,
        StaticLifetime, StoreOwnedExt,
    };
    pub use crate::proxy::{prefetch, Proxy};
    pub use crate::shard::{
        ElasticDesc, ElasticShards, HashRing, ShardedConnector, ShardedDesc,
    };
    pub use crate::store::{
        Blob, Connector, ConnectorDesc, FileConnector, MemoryConnector,
        MultiConnector, PendingGet, PendingWrite, Store, TcpKvConnector,
        ThrottledConnector,
    };
    pub use crate::stream::{
        Event, Metadata, Publisher, StreamConsumer, StreamProducer, Subscriber,
    };
}
