//! End-to-end elastic shard fabric: membership changes under concurrent
//! put/get load with zero read misses, full key-set convergence, slow
//! (latency-injected) shards, real TCP backends, and pre-rebalance
//! proxies resolving after the shard set changed.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::kv::{KvClient, KvServer};
use proxystore::net::ServerBuilder;
use proxystore::prelude::{Proxy, Store};
use proxystore::shard::{ElasticShards, ShardMembers};
use proxystore::store::{Connector, ConnectorDesc, MemoryConnector};
use proxystore::testing::fail::FlakyConnector;
use proxystore::testing::load::ReadProbe;

fn memory_members(n: usize) -> ShardMembers {
    (0..n).map(|id| (id, MemoryConnector::new())).collect()
}

#[test]
fn rebalance_under_concurrent_load_loses_nothing() {
    let elastic =
        ElasticShards::new("itest-load", memory_members(4), 1, 64).unwrap();
    let store = Store::new("itest", Arc::new(elastic.clone()));
    let objs: Vec<Bytes> =
        (0..400).map(|i| Bytes(vec![(i % 251) as u8; 64])).collect();
    let keys = store.put_many(&objs).unwrap();

    // Proxies minted before any rebalance: their factories carry the
    // generation-0 membership snapshot.
    let early_wire: Vec<Vec<u8>> = keys
        .iter()
        .take(8)
        .map(|k| store.proxy_from_key::<Bytes>(k).to_bytes())
        .collect();

    let probe = ReadProbe::spawn(&store, &keys, 3);
    // A writer keeps minting fresh objects throughout both migrations.
    let writer = {
        let store = store.clone();
        let stop = probe.stop_flag();
        std::thread::spawn(move || {
            let mut written = Vec::new();
            let mut i = 0u8;
            while !stop.load(Ordering::Relaxed) {
                written.push(store.put(&Bytes(vec![i; 48])).unwrap());
                i = i.wrapping_add(1);
                std::thread::sleep(Duration::from_millis(1));
            }
            written
        })
    };

    // Grow, then shrink, with load running the whole time.
    elastic.add_shard(4, MemoryConnector::new()).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));
    let after_grow = elastic.metrics();
    assert!(
        after_grow.keys_migrated > 0,
        "growing a loaded fabric must migrate something"
    );
    assert!(
        (after_grow.keys_migrated as usize) < keys.len() * 2 / 5,
        "{} of {} keys moved on grow — not the remapped ~1/5",
        after_grow.keys_migrated,
        keys.len()
    );

    elastic.remove_shard(1).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));

    let (reads, misses) = probe.finish();
    let written = writer.join().expect("writer thread");
    assert!(reads > 0, "readers never ran");
    assert_eq!(
        misses, 0,
        "read-through migration must never lose a read ({reads} reads)"
    );

    // Full convergence: the original key set AND everything written during
    // the migrations resolves through the final membership.
    assert_eq!(elastic.shard_ids(), vec![0, 2, 3, 4]);
    assert_eq!(elastic.generation(), 2);
    assert!(!elastic.migrating());
    for key in keys.iter().chain(written.iter()) {
        assert!(
            store.get::<Bytes>(key).unwrap().is_some(),
            "key {key} lost across the rebalances"
        );
    }

    // Migration stayed proportional: two single-shard changes on a 4-5-4
    // fabric remap ~1/5 + ~1/4, nowhere near the whole key set.
    let total = (keys.len() + written.len()) as u64;
    let m = elastic.metrics();
    assert!(
        m.keys_migrated < total * 6 / 10,
        "{} of {total} keys migrated — rebalancing is not incremental",
        m.keys_migrated
    );
    assert_eq!(m.rebalances, 2);
    assert_eq!(m.keys_failed, 0);

    // Pre-rebalance proxies re-attach to the live control plane and
    // resolve cold (cache invalidated to force a real fabric read).
    for wire in &early_wire {
        let p: Proxy<Bytes> = Proxy::from_bytes(wire).unwrap();
        p.factory().invalidate_cache();
        assert_eq!(p.resolve().unwrap().0.len(), 64);
    }
}

#[test]
fn rebalance_with_slow_shard_still_converges() {
    let flaky: Vec<Arc<FlakyConnector>> = (0..3)
        .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
        .collect();
    let members: ShardMembers = flaky
        .iter()
        .enumerate()
        .map(|(id, f)| (id, f.clone() as Arc<dyn Connector>))
        .collect();
    let elastic = ElasticShards::new("itest-slow", members, 1, 64).unwrap();
    let store = Store::new("slow", Arc::new(elastic.clone()));
    let objs: Vec<Bytes> =
        (0..150).map(|i| Bytes(vec![i as u8; 32])).collect();
    let keys = store.put_many(&objs).unwrap();

    // Shard 0 turns into a straggler: every operation pays 2ms. The
    // migration daemon has to read through it; readers keep hitting it.
    flaky[0].set_latency(Duration::from_millis(2));

    let probe = ReadProbe::spawn(&store, &keys, 2);
    let extra = MemoryConnector::new();
    elastic.add_shard(3, extra.clone()).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));
    let (reads, misses) = probe.finish();

    assert!(reads > 0);
    assert_eq!(misses, 0, "slow shard caused read misses during rebalance");
    let m = elastic.metrics();
    assert!(m.keys_migrated > 0);
    assert_eq!(m.keys_failed, 0, "latency is not failure: no key abandoned");
    assert!(
        flaky[0].delayed_ops() > 0,
        "the slow shard never served an operation"
    );
    assert_eq!(extra.len().unwrap() as u64, m.keys_migrated);
    for key in &keys {
        assert!(store.get::<Bytes>(key).unwrap().is_some());
    }
}

#[test]
fn elastic_over_real_tcp_backends() {
    let servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let members: ShardMembers = servers
        .iter()
        .enumerate()
        .map(|(id, s)| {
            (
                id,
                ConnectorDesc::TcpKv { addr: s.addr.to_string() }
                    .connect()
                    .unwrap(),
            )
        })
        .collect();
    let elastic = ElasticShards::new("itest-tcp", members, 1, 64).unwrap();
    let store = Store::new("tcp", Arc::new(elastic.clone()));
    let objs: Vec<Bytes> =
        (0..80).map(|i| Bytes(vec![i as u8; 256])).collect();
    let keys = store.put_many(&objs).unwrap();

    // Scale out onto a fresh server: the migration runs over real sockets
    // (MGET/MPUT/MDEL frames), not in-process shortcuts.
    let extra = ServerBuilder::new().spawn_kv().unwrap();
    elastic
        .add_shard(
            3,
            ConnectorDesc::TcpKv { addr: extra.addr.to_string() }
                .connect()
                .unwrap(),
        )
        .unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));

    let m = elastic.metrics();
    assert!(m.keys_migrated > 0);
    // The migrated keys physically reside on the new server.
    let probe = KvClient::connect(extra.addr).unwrap();
    let (resident, _, _) = probe.stats().unwrap();
    assert_eq!(resident, m.keys_migrated);
    // And the copies left the old servers: one copy per key fabric-wide.
    assert_eq!(elastic.len().unwrap(), keys.len());
    for (i, key) in keys.iter().enumerate() {
        let got: Option<Bytes> = store.get(key).unwrap();
        assert_eq!(
            got.map(|b| b.0),
            Some(vec![i as u8; 256]),
            "key {key} corrupted or lost by the wire migration"
        );
    }
}

#[test]
fn watch_armed_before_membership_change_survives_both_directions() {
    // Satellite acceptance: a watch armed before add_shard/remove_shard
    // still wakes after the epoch flips — the control plane re-arms it on
    // the post-flip placement, so a rebalance mid-wait never strands a
    // waiter.
    let elastic =
        ElasticShards::new("itest-watch", memory_members(3), 1, 64).unwrap();
    let store = Store::new("watch", Arc::new(elastic.clone()));
    let keys: Vec<String> = (0..32).map(|i| format!("armed-{i:02}")).collect();
    let handles: Vec<_> = keys.iter().map(|k| elastic.watch(k)).collect();

    // Grow, then shrink, with every watch still armed.
    elastic.add_shard(3, MemoryConnector::new()).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(30))));
    elastic.remove_shard(0).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(30))));
    assert!(
        handles.iter().all(|h| !h.is_complete()),
        "no watch may fire before its key exists"
    );

    for (i, key) in keys.iter().enumerate() {
        store.put_at(key, &Bytes(vec![i as u8; 16])).unwrap();
    }
    for (i, handle) in handles.into_iter().enumerate() {
        let got = handle.wait().unwrap();
        let value: Bytes = Bytes::from_bytes(&got).unwrap();
        assert_eq!(
            value.0,
            vec![i as u8; 16],
            "watch {i} stranded or corrupted by the rebalances"
        );
    }
}

#[test]
fn elastic_watch_over_tcp_fails_promptly_when_backend_dies() {
    // A watch whose only backing server dies mid-wait must surface the
    // failure instead of hanging the waiter forever.
    let mut server = ServerBuilder::new().spawn_kv().unwrap();
    let members: ShardMembers = vec![(
        0,
        ConnectorDesc::TcpKv { addr: server.addr.to_string() }
            .connect()
            .unwrap(),
    )];
    let elastic = ElasticShards::new("itest-dead", members, 1, 64).unwrap();
    let handle = elastic.watch("never-set");
    std::thread::sleep(Duration::from_millis(30));
    server.shutdown();
    let t0 = std::time::Instant::now();
    assert!(handle.wait().is_err(), "dead backend must fail the watch");
    assert!(t0.elapsed() < Duration::from_secs(5));
}

#[test]
fn sequential_membership_changes_serialize() {
    // Back-to-back changes with no explicit wait between them: the second
    // must block on the first's drain, never interleave epochs.
    let elastic =
        ElasticShards::new("itest-seq", memory_members(2), 1, 64).unwrap();
    let store = Store::new("seq", Arc::new(elastic.clone()));
    let objs: Vec<Bytes> = (0..120).map(|i| Bytes(vec![i as u8; 16])).collect();
    let keys = store.put_many(&objs).unwrap();

    elastic.add_shard(2, MemoryConnector::new()).unwrap();
    elastic.add_shard(3, MemoryConnector::new()).unwrap();
    elastic.remove_shard(0).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));

    assert_eq!(elastic.generation(), 3);
    assert_eq!(elastic.shard_ids(), vec![1, 2, 3]);
    assert_eq!(elastic.metrics().rebalances, 3);
    for key in &keys {
        assert!(store.get::<Bytes>(key).unwrap().is_some());
    }
    assert_eq!(elastic.len().unwrap(), keys.len());
}
