//! Ingress-plane integration tests: the event-loop reactor and the
//! threaded fallback under hostile wire conditions — partial frames,
//! mid-frame disconnects, connection churn, connection caps, and the
//! watch/long-poll paths that park on the event loop.

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use proxystore::codec::Bytes;
use proxystore::kv::{
    read_frame, write_frame, ClientOptions, FlushPolicy, KvClient, Request,
    Response,
};
use proxystore::net::{Ingress, ServerBuilder};

fn both_modes() -> Vec<Ingress> {
    if cfg!(target_os = "linux") {
        vec![Ingress::Threaded, Ingress::EventLoop]
    } else {
        vec![Ingress::Threaded]
    }
}

/// Encode `req` as one wire frame (length prefix + body).
fn frame_bytes(req: &Request) -> Vec<u8> {
    let mut buf = Vec::new();
    write_frame(&mut buf, req).expect("encode frame");
    buf
}

#[cfg(target_os = "linux")]
#[test]
fn event_ingress_reassembles_bytewise_dribbled_frames() {
    let server = ServerBuilder::new()
        .ingress(Ingress::EventLoop)
        .spawn_kv()
        .unwrap();
    let mut conn = TcpStream::connect(server.addr).unwrap();
    conn.set_nodelay(true).unwrap();

    // Feed the Set frame one byte at a time: every read the reactor
    // does sees a partial frame it must buffer and resume.
    let set = frame_bytes(&Request::Set {
        key: "dribble".into(),
        value: Bytes(vec![42u8; 64]),
    });
    for b in &set {
        conn.write_all(&[*b]).unwrap();
        conn.flush().unwrap();
    }
    assert_eq!(
        read_frame::<_, Response>(&mut conn).unwrap(),
        Some(Response::Ok)
    );

    // Same treatment for the readback, split into two arbitrary halves
    // with a pause between them.
    let get = frame_bytes(&Request::Get { key: "dribble".into() });
    let (a, b) = get.split_at(get.len() / 2);
    conn.write_all(a).unwrap();
    conn.flush().unwrap();
    std::thread::sleep(Duration::from_millis(20));
    conn.write_all(b).unwrap();
    conn.flush().unwrap();
    match read_frame::<_, Response>(&mut conn).unwrap() {
        Some(Response::Value(Some(v))) => assert_eq!(&v[..], &[42u8; 64][..]),
        other => panic!("unexpected reply: {other:?}"),
    }
}

#[test]
fn client_dying_mid_frame_leaves_server_healthy() {
    for ingress in both_modes() {
        let server =
            ServerBuilder::new().ingress(ingress).spawn_kv().unwrap();

        // Claim a 1 KiB frame, send 10 bytes of it, vanish.
        {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            conn.write_all(&1024u32.to_le_bytes()).unwrap();
            conn.write_all(&[0u8; 10]).unwrap();
            conn.flush().unwrap();
        }
        // And once more dying inside the length prefix itself.
        {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            conn.write_all(&[7u8, 0]).unwrap();
            conn.flush().unwrap();
        }

        let client = KvClient::connect(server.addr).unwrap();
        client.set("alive", Bytes(vec![1, 2, 3])).unwrap();
        assert_eq!(
            client.get("alive").unwrap(),
            Some(Bytes(vec![1, 2, 3])),
            "{ingress:?} server unusable after mid-frame disconnects"
        );
    }
}

#[test]
fn churn_1k_connections_both_modes() {
    // The threaded server retains a shutdown-clone per accepted socket,
    // so 1k churn wants fd headroom beyond stingy container defaults.
    let _ = proxystore::net::raise_nofile_limit(16_384);
    for ingress in both_modes() {
        let server =
            ServerBuilder::new().ingress(ingress).spawn_kv().unwrap();
        for i in 0..1000 {
            let mut conn = TcpStream::connect(server.addr).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            write_frame(&mut conn, &Request::Ping).unwrap();
            assert_eq!(
                read_frame::<_, Response>(&mut conn).unwrap(),
                Some(Response::Ok),
                "{ingress:?} ping failed at churn iteration {i}"
            );
            // Drop closes the socket; the server must reap it and keep
            // accepting.
        }
        let client = KvClient::connect(server.addr).unwrap();
        client.ping().unwrap();
    }
}

#[test]
fn max_connections_drops_excess_both_modes() {
    for ingress in both_modes() {
        let server = ServerBuilder::new()
            .ingress(ingress)
            .max_connections(2)
            .spawn_kv()
            .unwrap();

        let a = KvClient::connect(server.addr).unwrap();
        let b = KvClient::connect(server.addr).unwrap();
        a.ping().unwrap();
        b.ping().unwrap();

        // Third connection is accepted then immediately dropped; its
        // first read sees EOF (or a reset, depending on timing).
        let mut extra = TcpStream::connect(server.addr).unwrap();
        extra.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = write_frame(&mut extra, &Request::Ping);
        let reply = read_frame::<_, Response>(&mut extra);
        assert!(
            matches!(reply, Ok(None) | Err(_)),
            "{ingress:?} over-cap connection got served: {reply:?}"
        );

        // The admitted pair is unaffected.
        a.ping().unwrap();
        b.ping().unwrap();
        // And capacity frees up once one of them leaves.
        drop(a);
        std::thread::sleep(Duration::from_millis(50));
        let c = KvClient::connect(server.addr).unwrap();
        c.ping().unwrap();
    }
}

#[cfg(target_os = "linux")]
#[test]
fn notify_reaches_watch_parked_on_event_loop() {
    let server = ServerBuilder::new()
        .ingress(Ingress::EventLoop)
        .spawn_kv()
        .unwrap();
    let watcher = KvClient::connect(server.addr).unwrap();
    let setter = KvClient::connect(server.addr).unwrap();

    let handle = watcher.watch("parked");
    // FIFO barrier: once ping answers, the Watch frame before it has
    // been armed server-side.
    watcher.ping().unwrap();
    assert_eq!(watcher.watches_armed(), 1);

    setter.set("parked", Bytes(b"pushed".to_vec())).unwrap();
    let value = handle.wait().unwrap();
    assert_eq!(value.to_vec(), b"pushed".to_vec());
    assert_eq!(watcher.watches_armed(), 0);

    // The watcher's connection stayed a live request pipe throughout.
    watcher.ping().unwrap();
}

#[cfg(target_os = "linux")]
#[test]
fn broker_long_poll_parks_on_event_loop() {
    use proxystore::broker::BrokerClient;

    let server = ServerBuilder::new()
        .ingress(Ingress::EventLoop)
        .spawn_broker()
        .unwrap();
    let addr = server.addr;

    let fetcher = std::thread::spawn(move || {
        let sub = BrokerClient::connect(addr).unwrap();
        // Starts before anything is produced: must park (deferred on
        // the event loop), not return empty.
        sub.fetch("topic", 0, 1, Duration::from_secs(10)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(150));

    let publisher = BrokerClient::connect(addr).unwrap();
    publisher.produce("topic", Bytes(b"wake".to_vec())).unwrap();

    let entries = fetcher.join().unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].payload.0, b"wake".to_vec());
}

#[cfg(target_os = "linux")]
#[test]
fn tuned_client_options_work_over_event_ingress() {
    use proxystore::ops::Op;

    let server = ServerBuilder::new()
        .ingress(Ingress::EventLoop)
        .spawn_kv()
        .unwrap();
    let options = ClientOptions {
        pipeline_window: 4,
        flush: FlushPolicy::Coalesce {
            max_buffer: 16 * 1024,
            max_delay: Duration::from_millis(1),
        },
        ..ClientOptions::default()
    };
    let client = KvClient::connect_with(server.addr, options).unwrap();

    let mut handles = Vec::new();
    for i in 0..64 {
        handles.push(client.submit_op(Op::Put {
            key: format!("w-{i}"),
            data: vec![i as u8; 128],
        }));
        assert!(client.in_flight() <= 4, "window exceeded");
    }
    for h in handles {
        h.wait().unwrap().into_unit().unwrap();
    }
    for i in (0..64).step_by(13) {
        assert_eq!(
            client.get(&format!("w-{i}")).unwrap(),
            Some(Bytes(vec![i as u8; 128]))
        );
    }
}
