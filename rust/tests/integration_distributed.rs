//! Cross-module integration: TCP servers + store + futures + engine +
//! stream together, the way a deployment composes them.

use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::engine::{ClusterConfig, LocalCluster};
use proxystore::futures::ProxyFuture;
use proxystore::kv::KvServer;
use proxystore::net::ServerBuilder;
use proxystore::prelude::{Proxy, Store};
use proxystore::store::{TcpKvConnector, ThrottledConnector};
use proxystore::stream::{
    LogPublisher, LogSubscriber, Metadata, StreamConsumer, StreamProducer,
};

fn tcp_store(server: &KvServer, name: &str) -> Store {
    Store::new(
        name,
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    )
}

#[test]
fn proxies_cross_engine_boundaries_via_tcp_kv() {
    // Producer cluster and consumer cluster share NOTHING except the KV
    // server endpoint — the paper's engine-agnosticism claim.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let store = tcp_store(&server, "xengine");

    let cluster_a = Arc::new(LocalCluster::new(ClusterConfig::default()));
    let cluster_b = Arc::new(LocalCluster::new(ClusterConfig::default()));

    let fut: ProxyFuture<Bytes> = store.future();
    let fut_wire = fut.to_bytes();
    let proxy_wire = fut.proxy().to_bytes();

    // Engine A: producer task sets the future.
    let a = cluster_a.submit(
        Box::new(move |_, payload| {
            let f: ProxyFuture<Bytes> = ProxyFuture::from_bytes(&payload)?;
            std::thread::sleep(Duration::from_millis(40));
            f.set_result(&Bytes(vec![1, 2, 3]))?;
            Ok(vec![])
        }),
        fut_wire,
    );
    // Engine B: consumer task resolves the proxy.
    let b = cluster_b.submit(
        Box::new(move |_, payload| {
            let p: Proxy<Bytes> = Proxy::from_bytes(&payload)?;
            Ok(p.into_inner()?.0)
        }),
        proxy_wire,
    );
    assert_eq!(b.wait().unwrap(), vec![1, 2, 3]);
    a.wait().unwrap();
}

#[test]
fn stream_over_tcp_broker_and_tcp_kv_with_worker_pool() {
    // Full Fig 4 topology with real sockets: producer → broker(event) +
    // kv(bulk); dispatcher → worker pool; workers resolve bulk from kv.
    let kv = ServerBuilder::new().spawn_kv().unwrap();
    let broker = ServerBuilder::new().spawn_broker().unwrap();
    let n_items = 10usize;
    let kv_addr = kv.addr;
    let broker_addr = broker.addr;

    let producer = std::thread::spawn(move || {
        let store = Store::new(
            "s",
            Arc::new(TcpKvConnector::connect(kv_addr).unwrap()),
        );
        let mut producer = StreamProducer::new(
            LogPublisher::connect(broker_addr).unwrap(),
            Some(store),
        );
        for i in 0..n_items {
            let data = Bytes(vec![i as u8; 10_000]);
            let mut md = Metadata::new();
            md.insert("i".into(), i.to_string());
            producer.send("frames", &data, md).unwrap();
        }
        producer.close_topic("frames").unwrap();
    });

    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: 3,
        ..Default::default()
    }));
    let mut consumer = StreamConsumer::new(
        LogSubscriber::connect(broker.addr, "frames").unwrap(),
    );
    let mut futs = Vec::new();
    while let Some((proxy, md)) = consumer
        .next_proxy::<Bytes>(Some(Duration::from_secs(10)))
        .unwrap()
    {
        let i: usize = md["i"].parse().unwrap();
        let wire = proxy.to_bytes();
        futs.push((i, cluster.submit(
            Box::new(move |_, payload| {
                let p: Proxy<Bytes> = Proxy::from_bytes(&payload)?;
                let data = p.into_inner()?;
                Ok(vec![data.0[0], data.0.len() as u8])
            }),
            wire,
        )));
    }
    producer.join().unwrap();
    assert_eq!(futs.len(), n_items);
    for (i, fut) in futs {
        let out = fut.wait().unwrap();
        assert_eq!(out[0] as usize, i);
        assert_eq!(out[1] as usize, 10_000 % 256);
    }
    // Bulk bytes all went through the KV server, not the broker.
    let (keys, bytes, _) = kv.state().stats();
    assert_eq!(keys as usize, n_items);
    assert!(bytes >= (n_items * 10_000) as u64);
    assert!(broker.state().gauge.get() < 4096);
}

#[test]
fn throttled_tcp_store_is_slower_but_correct() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let fast = tcp_store(&server, "fast");
    let slow = Store::new(
        "slow",
        ThrottledConnector::wrap(
            Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
            Duration::from_millis(10),
            1.0e9,
        ),
    );
    let data = Bytes(vec![9; 50_000]);

    let t0 = std::time::Instant::now();
    let k1 = fast.put(&data).unwrap();
    let fast_t = t0.elapsed();
    let t0 = std::time::Instant::now();
    let k2 = slow.put(&data).unwrap();
    let slow_t = t0.elapsed();
    // The throttled put pays one 10 ms simulated latency on top of the
    // real socket round-trip.
    assert!(slow_t >= Duration::from_millis(9), "{slow_t:?} vs {fast_t:?}");
    assert!(slow_t > fast_t, "{slow_t:?} vs {fast_t:?}");
    // Same backing server: both readable from either store.
    assert_eq!(fast.get::<Bytes>(&k2).unwrap().unwrap(), data);
    assert_eq!(slow.get::<Bytes>(&k1).unwrap().unwrap(), data);
}

#[test]
fn future_timeout_and_late_set_over_tcp() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let store = tcp_store(&server, "late");
    let fut: ProxyFuture<u32> = store.future();
    // Timeout-bounded proxy fails fast...
    let p = fut.proxy_with_timeout(Duration::from_millis(50));
    assert!(p.resolve().is_err());
    // ...but the future itself can still be completed and read afterwards.
    fut.set_result(&7).unwrap();
    assert_eq!(fut.result(Some(Duration::from_secs(1))).unwrap(), 7);
}

#[test]
fn many_concurrent_futures_one_server() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let store = tcp_store(&server, "many");
    let futures: Vec<ProxyFuture<u64>> =
        (0..16).map(|_| store.future()).collect();
    let consumers: Vec<_> = futures
        .iter()
        .map(|f| {
            let p = f.proxy();
            std::thread::spawn(move || *p.resolve().unwrap())
        })
        .collect();
    for (i, f) in futures.iter().enumerate() {
        f.set_result(&(i as u64 * 11)).unwrap();
    }
    for (i, c) in consumers.into_iter().enumerate() {
        assert_eq!(c.join().unwrap(), i as u64 * 11);
    }
}
