//! End-to-end watch/notify plane: no head-of-line blocking on the
//! pipelined connection, push-mode wakes across clients and fabrics,
//! prompt failure on server death, and the futures layer (result_async,
//! when_all/when_any, atomic set_result) riding it.

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::futures::{when_all, when_any, ProxyFuture};
use proxystore::kv::{KvClient, KvServer};
use proxystore::net::ServerBuilder;
use proxystore::prelude::Store;
use proxystore::shard::ShardedConnector;
use proxystore::store::{Connector, ConnectorDesc, TcpKvConnector};

#[test]
fn parked_watch_never_stalls_the_pipelined_connection() {
    // The acceptance test for no head-of-line blocking: hold a watch that
    // never fires on a pipelined connection while ordinary traffic on the
    // SAME connection keeps completing. The old WaitGet design parked the
    // FIFO response stream here; the watch plane must not.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = KvClient::connect(server.addr).unwrap();
    let parked = client.watch("never-fires");
    assert_eq!(client.watches_armed(), 1);

    let t0 = Instant::now();
    for i in 0..200 {
        let key = format!("traffic-{i}");
        client.set(&key, Bytes(vec![i as u8])).unwrap();
        assert_eq!(client.get(&key).unwrap(), Some(Bytes(vec![i as u8])));
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "traffic behind a parked watch must flow at full speed"
    );
    assert!(!parked.is_complete(), "nothing ever stored the watched key");
    assert_eq!(client.in_flight(), 0);

    // The parked watch is still live: a late producer wakes it.
    client.set("never-fires", Bytes(vec![9, 9])).unwrap();
    assert_eq!(parked.wait().unwrap().to_vec(), vec![9, 9]);
}

#[test]
fn watch_wakes_across_sharded_tcp_fabric() {
    // Producer and consumer on separate fabric handles over real
    // sockets: the wake crosses the wire as one Notify push from the
    // owning shard.
    let servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let backends: Vec<Arc<dyn Connector>> = servers
        .iter()
        .map(|s| {
            Arc::new(TcpKvConnector::connect(s.addr).unwrap())
                as Arc<dyn Connector>
        })
        .collect();
    let router = Arc::new(ShardedConnector::new(backends, 2, 64).unwrap());
    let store = Store::new("watch-tcp", router.clone());

    let key = store.new_key();
    let pending = store.watch_async::<Bytes>(&key);
    assert!(!pending.is_complete());

    // An independent fabric handle (same servers, fresh connections)
    // produces the value.
    let desc = router.desc();
    let producer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(30));
        let conn = ConnectorDesc::from_bytes(&desc.to_bytes())
            .unwrap()
            .connect()
            .unwrap();
        conn.put(&key, vec![7; 128]).unwrap();
    });
    assert_eq!(pending.wait().unwrap(), Some(Bytes(vec![7; 128])));
    producer.join().unwrap();
}

#[test]
fn watch_fails_promptly_when_server_dies_mid_wait() {
    let mut server = ServerBuilder::new().spawn_kv().unwrap();
    let conn = TcpKvConnector::connect(server.addr).unwrap();
    let handle = conn.watch("never-set");
    std::thread::sleep(Duration::from_millis(30));
    server.shutdown();
    let t0 = Instant::now();
    assert!(
        handle.wait().is_err(),
        "a watch whose server died must fail, not hang"
    );
    assert!(t0.elapsed() < Duration::from_secs(2));
}

#[test]
fn wait_get_shares_the_connection_with_its_own_producer() {
    // Consumer parks in wait_get on the SAME TcpKvConnector whose shared
    // client the producer then writes through: only possible because the
    // wait rides an out-of-band watch instead of parking the pipe.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let conn = Arc::new(TcpKvConnector::connect(server.addr).unwrap());
    let c2 = conn.clone();
    let waiter = std::thread::spawn(move || {
        c2.wait_get("meet", Some(Duration::from_secs(5))).unwrap()
    });
    std::thread::sleep(Duration::from_millis(30));
    conn.put("meet", vec![5; 32]).unwrap();
    assert_eq!(waiter.join().unwrap().map(|b| b.to_vec()), Some(vec![5; 32]));
}

#[test]
fn futures_when_all_and_result_async_across_sharded_store() {
    // Sec IV-A's dynamic task graph shape: N producers resolve futures
    // bound to a sharded store; the consumer arms everything up front and
    // parks once per key.
    let backends: Vec<Arc<dyn Connector>> = (0..4)
        .map(|_| proxystore::store::MemoryConnector::new())
        .collect();
    let store =
        Store::new("futs", Arc::new(ShardedConnector::new(backends, 1, 64).unwrap()));
    let futs: Vec<ProxyFuture<u64>> = (0..12).map(|_| store.future()).collect();

    // Overlap: arm one async handle before any producer runs.
    let early = futs[7].result_async().unwrap();
    assert!(!early.is_complete());

    let producers: Vec<_> = futs
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let f = f.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(5 * (i as u64 % 4)));
                f.set_result(&(i as u64 * i as u64)).unwrap();
            })
        })
        .collect();

    let all = when_all(&futs, Some(Duration::from_secs(10))).unwrap();
    assert_eq!(all, (0..12).map(|i| i * i).collect::<Vec<u64>>());
    assert_eq!(early.wait().unwrap(), 49);
    for p in producers {
        p.join().unwrap();
    }

    // when_any on a fresh set: the single resolved member wins.
    let cold: Vec<ProxyFuture<u64>> = (0..4).map(|_| store.future()).collect();
    cold[2].set_result(&1234).unwrap();
    let (idx, v) = when_any(&cold, Some(Duration::from_secs(5))).unwrap();
    assert_eq!((idx, v), (2, 1234));
}

#[test]
fn set_result_is_atomic_over_tcp() {
    // The TOCTOU regression, over a real wire: N producers race one
    // future whose channel is a TCP KV server; SetNx decides the winner.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let store = Store::new(
        "race",
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    );
    let fut: ProxyFuture<u64> = store.future();
    let wins: Vec<bool> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let f = fut.clone();
                s.spawn(move || f.set_result(&(i as u64)).is_ok())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(
        wins.iter().filter(|&&w| w).count(),
        1,
        "exactly one producer may win over the wire"
    );
    let winner = wins.iter().position(|&w| w).unwrap() as u64;
    assert_eq!(fut.result(Some(Duration::from_secs(5))).unwrap(), winner);
}

#[test]
fn many_waiters_one_put_fan_out() {
    // 64 watches parked on one key over ONE pipelined connection; a
    // single put wakes every one of them.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = Arc::new(KvClient::connect(server.addr).unwrap());
    let handles: Vec<_> = (0..64).map(|_| client.watch("fan")).collect();
    assert_eq!(client.watches_armed(), 64);
    let setter = KvClient::connect(server.addr).unwrap();
    setter.set("fan", Bytes(vec![3; 16])).unwrap();
    for h in handles {
        assert_eq!(h.wait().unwrap().to_vec(), vec![3; 16]);
    }
    assert_eq!(client.watches_armed(), 0);
    assert_eq!(server.state().watch_count(), 0);
}
