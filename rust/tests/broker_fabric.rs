//! End-to-end partitioned broker fabric: real TCP broker servers, keyed
//! and round-robin production, consumer-group fan-in with rebalance, and
//! instance failure injection.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use proxystore::broker::{
    assign_partitions, BrokerFabric, BrokerServer, BrokerState,
    PartitionBroker, PartitionedConsumer, PartitionedProducer, Partitioner,
};
use proxystore::codec::Bytes;
use proxystore::net::ServerBuilder;
use proxystore::stream::{
    Metadata, PartitionedLogPublisher, PartitionedLogSubscriber,
    StreamConsumer, StreamProducer,
};
use proxystore::store::Store;
use proxystore::testing::fail::FlakyBroker;

fn tcp_fabric(n: usize, partitions: u32) -> (BrokerFabric, Vec<BrokerServer>) {
    let servers: Vec<BrokerServer> =
        (0..n).map(|_| ServerBuilder::new().spawn_broker().unwrap()).collect();
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    (BrokerFabric::connect(&addrs, partitions).unwrap(), servers)
}

#[test]
fn tcp_fabric_preserves_per_partition_order() {
    let (fabric, servers) = tcp_fabric(3, 8);
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::ByKey);
    // Four keys, 10 events each, interleaved.
    for i in 0..40u8 {
        let key = format!("key-{}", i % 4);
        producer.produce("t", Some(&key), Bytes(vec![i])).unwrap();
    }

    // A second, independently built fabric (fresh TCP connections) agrees
    // on placement and sees every event in per-partition order.
    let addrs: Vec<_> = servers.iter().map(|s| s.addr).collect();
    let fabric2 = BrokerFabric::connect(&addrs, 8).unwrap();
    let mut consumer = PartitionedConsumer::new(fabric2, "t", 0, 1).unwrap();
    let mut per_key: HashMap<u8, Vec<u8>> = HashMap::new();
    let mut seen = 0;
    while seen < 40 {
        let got = consumer.poll(Duration::from_secs(5)).unwrap();
        assert!(!got.is_empty(), "starved at {seen}/40");
        for (_, e) in got {
            let v = e.payload.0[0];
            per_key.entry(v % 4).or_default().push(v);
            seen += 1;
        }
    }
    // Same key -> same partition -> production order preserved.
    for (k, vals) in per_key {
        let expect: Vec<u8> = (0..40u8).filter(|i| i % 4 == k).collect();
        assert_eq!(vals, expect, "key class {k} misordered");
    }
}

#[test]
fn tcp_batched_produce_many_lands_in_order() {
    let (fabric, _servers) = tcp_fabric(2, 4);
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
    let events: Vec<(Option<String>, Bytes)> =
        (0..32u8).map(|i| (None, Bytes(vec![i]))).collect();
    let placed = producer.produce_many("t", events).unwrap();
    assert_eq!(placed.len(), 32);
    // Round-robin: event i on partition i % 4, offsets dense per partition.
    for (i, &(p, o)) in placed.iter().enumerate() {
        assert_eq!(p, (i % 4) as u32);
        assert_eq!(o, (i / 4) as u64);
    }
    assert_eq!(fabric.end_offsets("t").unwrap(), vec![8, 8, 8, 8]);
}

#[test]
fn group_rebalance_covers_all_partitions_exactly_once() {
    // The assignment invariant at every group size...
    for members in 1..=5usize {
        let mut owned = vec![0u32; 12];
        for m in 0..members {
            for p in assign_partitions(12, members, m) {
                owned[p as usize] += 1;
            }
        }
        assert!(owned.iter().all(|&c| c == 1), "members={members}: {owned:?}");
    }

    // ...and live: two members split the stream; after one "leaves", the
    // survivor re-joins as the only member and picks up the leaver's
    // partitions from the group's committed offsets.
    let (fabric, _servers) = tcp_fabric(2, 4);
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
    for i in 0..20u8 {
        producer.produce("t", None, Bytes(vec![i])).unwrap();
    }
    let mut survivor_saw = Vec::new();
    {
        let mut m0 = PartitionedConsumer::with_group(
            fabric.clone(), "t", "g", 0, 2,
        )
        .unwrap();
        let mut m1 = PartitionedConsumer::with_group(
            fabric.clone(), "t", "g", 1, 2,
        )
        .unwrap();
        assert_eq!(m0.assigned(), &[0, 2]);
        assert_eq!(m1.assigned(), &[1, 3]);
        // m0 drains its half and commits; m1 "crashes" before consuming.
        loop {
            let got = m0.poll(Duration::ZERO).unwrap();
            if got.is_empty() {
                break;
            }
            survivor_saw.extend(got.iter().map(|(_, e)| e.payload.0[0]));
        }
        m0.commit().unwrap();
    }
    // Rebalance: the survivor now owns everything; committed offsets on
    // its old partitions skip what it already consumed, the leaver's
    // partitions replay from 0.
    let mut solo =
        PartitionedConsumer::with_group(fabric, "t", "g", 0, 1).unwrap();
    assert_eq!(solo.assigned(), &[0, 1, 2, 3]);
    loop {
        let got = solo.poll(Duration::ZERO).unwrap();
        if got.is_empty() {
            break;
        }
        survivor_saw.extend(got.iter().map(|(_, e)| e.payload.0[0]));
    }
    survivor_saw.sort_unstable();
    assert_eq!(survivor_saw, (0..20u8).collect::<Vec<_>>());
}

#[test]
fn dead_instance_degrades_only_its_partitions() {
    let flaky: Vec<Arc<FlakyBroker>> = (0..3)
        .map(|_| FlakyBroker::wrap(Arc::new(BrokerState::new()) as _))
        .collect();
    let fabric = BrokerFabric::new(
        flaky.iter().map(|f| f.clone() as Arc<dyn PartitionBroker>).collect(),
        9,
    )
    .unwrap();
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
    for i in 0..18u8 {
        producer.produce("t", None, Bytes(vec![i])).unwrap();
    }

    // Kill the instance hosting partition 0: its partitions become
    // unavailable, the rest of the stream keeps flowing (losses explicit,
    // not silent).
    let victim = fabric.instance_for("t", 0);
    flaky[victim].set_down(true);
    let dead_parts: Vec<u32> =
        (0..9).filter(|&p| fabric.instance_for("t", p) == victim).collect();
    assert!(!dead_parts.is_empty(), "victim hosts partition 0 by choice");
    assert!(dead_parts.len() < 9, "one instance must not host everything");

    let mut consumer =
        PartitionedConsumer::new(fabric.clone(), "t", 0, 1).unwrap();
    let mut live_events = 0;
    loop {
        match consumer.poll(Duration::ZERO) {
            Ok(got) if got.is_empty() => break,
            Ok(got) => {
                for (p, _) in &got {
                    assert!(
                        !dead_parts.contains(p),
                        "event from a dead partition {p}"
                    );
                }
                live_events += got.len();
            }
            // Fully drained live instances surface the dead one.
            Err(_) => break,
        }
    }
    assert!(consumer.instance_errors() > 0, "outage went unnoticed");
    let expected_live = (0..18u8)
        .filter(|&i| !dead_parts.contains(&(u32::from(i) % 9)))
        .count();
    assert_eq!(live_events, expected_live);

    // Producing to a dead partition errors; a live one succeeds.
    let inst_of = |p: u32| fabric.instance_for("t", p);
    let dead_p = dead_parts[0];
    let live_p = (0..9).find(|&p| inst_of(p) != victim).unwrap();
    assert!(fabric
        .instance(inst_of(dead_p))
        .produce_to("t", dead_p, Bytes(vec![99]))
        .is_err());
    fabric
        .instance(inst_of(live_p))
        .produce_to("t", live_p, Bytes(vec![99]))
        .unwrap();

    // Recovery: the dead partitions' backlog is intact and ordered.
    flaky[victim].set_down(false);
    let mut recovered = PartitionedConsumer::new(fabric, "t", 0, 1).unwrap();
    let mut total = 0;
    while total < 19 {
        let got = recovered.poll(Duration::from_secs(5)).unwrap();
        assert!(!got.is_empty(), "recovery starved at {total}/19");
        total += got.len();
    }
}

#[test]
fn streaming_over_tcp_fabric_with_group_members() {
    let (fabric, _servers) = tcp_fabric(2, 4);
    let store = Store::memory("fabric-stream");
    let mut producer = StreamProducer::new(
        PartitionedLogPublisher::new(fabric.clone()),
        Some(store.clone()),
    );
    for i in 0..12u64 {
        let mut md = Metadata::new();
        md.insert("i".into(), i.to_string());
        producer.send("t", &i, md).unwrap();
    }
    producer.close_topic("t").unwrap();

    // Two group members consume disjoint partition slices in parallel
    // threads; together they see everything, each closes on its own EOS.
    let handles: Vec<_> = (0..2)
        .map(|m| {
            let fabric = fabric.clone();
            std::thread::spawn(move || {
                let mut consumer = StreamConsumer::new(
                    PartitionedLogSubscriber::with_group(
                        fabric, "t", "workers", m, 2,
                    )
                    .unwrap(),
                );
                let mut got = Vec::new();
                while let Some((p, _)) = consumer
                    .next_proxy::<u64>(Some(Duration::from_secs(5)))
                    .unwrap()
                {
                    got.push(*p.resolve().unwrap());
                }
                got
            })
        })
        .collect();
    let mut all: Vec<u64> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    all.sort_unstable();
    assert_eq!(all, (0..12).collect::<Vec<_>>());
}
