//! End-to-end shard fabric: real TCP KV backends, serialized sharded
//! proxies resolving through fresh connections, batched wire ops, and
//! replica failover with an actual server death.

use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::error::Error;
use proxystore::kv::{KvClient, KvServer};
use proxystore::net::ServerBuilder;
use proxystore::prelude::{prefetch, Proxy, Store};
use proxystore::shard::{HashRing, ShardedConnector, ShardedDesc};
use proxystore::store::{Connector, ConnectorDesc};

fn tcp_fabric_desc(servers: &[KvServer], replicas: usize) -> ShardedDesc {
    ShardedDesc::new(
        servers
            .iter()
            .map(|s| ConnectorDesc::TcpKv { addr: s.addr.to_string() })
            .collect(),
    )
    .with_replicas(replicas)
}

#[test]
fn sharded_proxy_resolves_through_codec_roundtrip() {
    // The "separate process" path minus the fork: the proxy's wire bytes
    // are decoded into a fresh factory whose descriptor rebuilds the whole
    // fabric over TCP. Nothing from the minting side is reused except the
    // serialized bytes and the live servers.
    let servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let store = Store::new(
        "mint",
        tcp_fabric_desc(&servers, 1).connect().unwrap(),
    );
    let payload = Bytes(vec![77; 512 * 1024]);
    let proxy: Proxy<Bytes> = store.proxy(&payload).unwrap();
    let wire = proxy.to_bytes();
    assert!(
        wire.len() < 1024,
        "sharded proxy wire form is {} bytes, not a cheap reference",
        wire.len()
    );

    // Decode on the "consumer side" and resolve cold (bypass the local
    // blob cache to force a real fabric read).
    let shipped: Proxy<Bytes> = Proxy::from_bytes(&wire).unwrap();
    shipped.factory().invalidate_cache();
    assert_eq!(shipped.resolve().unwrap().0, payload.0);

    // The routing is deterministic: exactly one backend holds the key.
    let holders = servers
        .iter()
        .filter(|s| {
            let c = KvClient::connect(s.addr).unwrap();
            c.exists(proxy.key()).unwrap()
        })
        .count();
    assert_eq!(holders, 1);
}

#[test]
fn ring_agrees_with_deserialized_fabric() {
    // Two independently decoded fabrics route identically — the property
    // that makes a sharded proxy self-contained.
    let servers: Vec<KvServer> =
        (0..4).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let desc = tcp_fabric_desc(&servers, 1).desc();
    let bytes = desc.to_bytes();
    let a = ConnectorDesc::from_bytes(&bytes).unwrap().connect().unwrap();
    let b = ConnectorDesc::from_bytes(&bytes).unwrap().connect().unwrap();
    let ring = HashRing::new(4, proxystore::shard::DEFAULT_VNODES);
    for i in 0..32 {
        let key = format!("agree-{i}");
        a.put(&key, vec![i as u8]).unwrap();
        let got = b.get(&key).unwrap().map(|v| v.to_vec());
        assert_eq!(got, Some(vec![i as u8]));
        // And the expected primary server actually holds it.
        let expect = ring.shard_for(&key);
        let c = KvClient::connect(servers[expect].addr).unwrap();
        assert!(c.exists(&key).unwrap(), "key {key} not on ring shard {expect}");
    }
}

#[test]
fn batched_ops_one_round_trip_per_shard_over_tcp() {
    let servers: Vec<KvServer> =
        (0..2).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let store = Store::new(
        "batch",
        tcp_fabric_desc(&servers, 1).connect().unwrap(),
    );
    let objs: Vec<Bytes> = (0..40).map(|i| Bytes(vec![i as u8; 100])).collect();

    let ops_before: u64 = servers
        .iter()
        .map(|s| s.state().ops_served())
        .sum();
    let keys = store.put_many(&objs).unwrap();
    let got: Vec<Option<Bytes>> = store.get_many(&keys).unwrap();
    let ops_after: u64 = servers
        .iter()
        .map(|s| s.state().ops_served())
        .sum();
    for (i, b) in got.iter().enumerate() {
        assert_eq!(b.as_ref().unwrap().0, vec![i as u8; 100]);
    }
    // 40 puts + 40 gets over 2 shards must cost ~4 engine ops (one
    // MPUT + one MGET per shard), not ~80. Allow slack for key salting.
    assert!(
        ops_after - ops_before <= 8,
        "batched ops hit the engine {} times",
        ops_after - ops_before
    );

    // Partial miss and empty batch through the full stack.
    let mixed = vec![keys[0].clone(), "nope".to_string(), keys[39].clone()];
    let got: Vec<Option<Bytes>> = store.get_many(&mixed).unwrap();
    assert!(got[0].is_some() && got[1].is_none() && got[2].is_some());
    let empty: Vec<Option<Bytes>> = store.get_many(&[]).unwrap();
    assert!(empty.is_empty());
}

#[test]
fn replica_failover_with_real_server_death() {
    let mut servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let router = Arc::new(
        ShardedConnector::new(
            servers
                .iter()
                .map(|s| {
                    ConnectorDesc::TcpKv { addr: s.addr.to_string() }
                        .connect()
                        .unwrap()
                })
                .collect(),
            2,
            0,
        )
        .unwrap(),
    );
    let store = Store::new("failover", router.clone());
    // 48 keys over 3 shards: the chance none has shard 0 as primary (which
    // the final fallback assertion needs) is (2/3)^48 ≈ 4e-9.
    let objs: Vec<Bytes> = (0..48).map(|i| Bytes(vec![i as u8; 256])).collect();
    let keys = store.put_many(&objs).unwrap();

    // Kill backend 0 for real: sockets close, later reads error there.
    servers[0].shutdown();
    let dead = servers.remove(0);
    drop(dead);
    std::thread::sleep(Duration::from_millis(50));

    let got: Vec<Option<Bytes>> = store.get_many(&keys).unwrap();
    for (i, b) in got.iter().enumerate() {
        assert_eq!(
            b.as_ref().map(|v| v.0.clone()),
            Some(vec![i as u8; 256]),
            "object {i} lost after single-backend death with R=2"
        );
    }
    assert!(
        router.fallback_reads() > 0,
        "some keys must have had shard 0 as primary"
    );
}

#[test]
fn prefetch_over_tcp_fabric_amortizes_resolution() {
    let servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let store = Store::new(
        "pref",
        tcp_fabric_desc(&servers, 1).connect().unwrap(),
    );
    let objs: Vec<Bytes> = (0..16).map(|i| Bytes(vec![i as u8; 4096])).collect();
    let proxies = store.proxy_many(&objs).unwrap();
    let shipped: Vec<Proxy<Bytes>> = proxies
        .iter()
        .map(|p| Proxy::from_bytes(&p.to_bytes()).unwrap())
        .collect();
    let fetched = prefetch(&shipped).unwrap();
    assert_eq!(fetched, 16);
    for (i, p) in shipped.iter().enumerate() {
        assert_eq!(p.resolve().unwrap().0, vec![i as u8; 4096]);
    }
}

#[test]
fn unreachable_fabric_errors_cleanly() {
    // Descriptor pointing at ports nobody listens on: connect() fails
    // loudly rather than hanging (the connector connects eagerly).
    let desc = ShardedDesc::new(vec![
        ConnectorDesc::TcpKv { addr: "127.0.0.1:1".into() },
        ConnectorDesc::TcpKv { addr: "127.0.0.1:2".into() },
    ]);
    match desc.connect() {
        Err(Error::Io(_)) | Err(Error::Connector(_)) | Err(Error::Config(_)) => {}
        Err(other) => panic!("unexpected error kind: {other}"),
        Ok(_) => panic!("connected to a port nobody listens on"),
    }
}
