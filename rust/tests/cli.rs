//! Launcher integration: the `proxystore` binary's commands run end to end.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_proxystore"))
        .args(args)
        .env("PROXYSTORE_ARTIFACTS", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .output()
        .expect("spawn proxystore");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_and_version() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("COMMANDS"));
    let (ok, text) = run(&["version"]);
    assert!(ok);
    assert!(text.contains("proxystore 0.1.0"));
    // No args prints help too.
    let (ok, text) = run(&[]);
    assert!(ok);
    assert!(text.contains("USAGE"));
}

#[test]
fn unknown_command_fails_cleanly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn quickstart_runs() {
    let (ok, text) = run(&["quickstart"]);
    assert!(ok, "{text}");
    assert!(text.contains("consumer observed: 42"));
    assert!(text.contains("evicted after owner drop: true"));
}

#[test]
fn fig5_small_run() {
    let (ok, text) = run(&[
        "fig5", "--tasks", "4", "--task-ms", "40", "--size", "100000",
        "--f", "0.5",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("[proxyfuture] makespan"));
    assert!(text.contains("makespan ="));
}

#[test]
fn genomes_small_run() {
    let (ok, text) = run(&[
        "genomes", "--mode", "proxyfuture", "--individuals", "8",
        "--chunks", "2", "--snps", "100",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("overlapping variants found"));
}

#[test]
fn mof_small_run_uses_artifacts() {
    let (ok, text) =
        run(&["mof", "--mode", "ownership", "--rounds", "1", "--generators", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("best score"));
    assert!(text.contains("final = 0"));
}

#[test]
fn shard_small_run() {
    let (ok, text) = run(&[
        "shard", "--shards", "2", "--replicas", "2", "--keys", "8",
        "--size", "4096",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("batched throughput"));
    assert!(text.contains("8/8 objects still readable"));
    assert!(text.contains("resolves to 4096B"));
}

#[test]
fn broker_shard_small_run() {
    let (ok, text) = run(&[
        "broker-shard", "--instances", "2", "--partitions", "4",
        "--events", "32", "--size", "4096",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("batched produce/fetch throughput"));
    assert!(text.contains("fetch speedup"));
    assert!(text.contains("per-partition order preserved: true"));
    assert!(text.contains("instance 0 restored: produce succeeds again"));
}

#[test]
fn stats_small_run() {
    let (ok, text) =
        run(&["stats", "--shards", "2", "--keys", "16", "--size", "1024"]);
    assert!(ok, "{text}");
    assert!(text.contains("put+get 16 objects, 16 hits"));
    assert!(text.contains("snapshot fetched over the wire"));
    assert!(text.contains("== telemetry snapshot =="));
    assert!(text.contains("kv.client.ops"));
    assert!(text.contains("kv.server.frames_in"));
    assert!(text.contains("trace events"));
}

#[test]
fn bad_option_value_fails_cleanly() {
    let (ok, text) = run(&["fig5", "--tasks", "many"]);
    assert!(!ok);
    assert!(text.contains("cannot parse"));
}
