//! Failure injection: the stack must fail loudly and recover where the
//! paper's design says it can (fault-tolerant broker/channel ⇒
//! fault-tolerant stream; ownership is NOT fault-tolerant to client
//! crashes, but engines may rerun tasks).

use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::engine::{ClusterConfig, LocalCluster, StoreExecutor, TaskArg};
use proxystore::error::Error;
use proxystore::kv::KvClient;
use proxystore::net::ServerBuilder;
use proxystore::ownership::{take_violations, LeaseLifetime, Lifetime, StoreOwnedExt};
use proxystore::ownership::lifetime::StoreLifetimeExt;
use proxystore::prelude::{Proxy, Store};
use proxystore::store::TcpKvConnector;

#[test]
fn kv_server_death_surfaces_as_connector_error() {
    let mut server = ServerBuilder::new().spawn_kv().unwrap();
    let store = Store::new(
        "dead",
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    );
    let proxy: Proxy<Bytes> = store.proxy(&Bytes(vec![1; 1000])).unwrap();
    proxy.resolve().unwrap(); // works while alive

    // A second object that is stored but never resolved: nothing of it can
    // be in the process-local resolution cache.
    let cold: Proxy<Bytes> = store.proxy(&Bytes(vec![2; 1000])).unwrap();

    server.shutdown();
    drop(server); // sockets close
    std::thread::sleep(Duration::from_millis(50));

    // The already-resolved proxy still serves from the local cache — the
    // documented pass-by-value copy semantics…
    let warm: Proxy<Bytes> = Proxy::from_bytes(&proxy.to_bytes()).unwrap();
    assert!(warm.resolve().is_ok(), "cached copy should survive");
    // …but an uncached resolution must error, not hang or panic.
    let fresh: Proxy<Bytes> = Proxy::from_bytes(&cold.to_bytes()).unwrap();
    fresh.factory().invalidate_cache(); // belt and braces
    match fresh.resolve() {
        Err(_) => {}
        Ok(_) => panic!("resolution against a dead server must fail"),
    }
}

#[test]
fn kv_restart_loses_volatile_state_but_serves_new_writes() {
    // The redis-sim store is volatile (like the paper's Redis deployments
    // without persistence): a restart is an empty server on a new port.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let c = KvClient::connect(server.addr).unwrap();
    c.set("k", Bytes(vec![1])).unwrap();
    drop(server);

    let server2 = ServerBuilder::new().spawn_kv().unwrap();
    let c2 = KvClient::connect(server2.addr).unwrap();
    assert_eq!(c2.get("k").unwrap(), None);
    c2.set("k", Bytes(vec![2])).unwrap();
    assert_eq!(c2.get("k").unwrap(), Some(Bytes(vec![2])));
}

#[test]
fn task_panic_releases_borrows_and_reruns_cleanly() {
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: 1,
        ..Default::default()
    }));
    let store = Store::memory("panic");
    let executor = StoreExecutor::new(cluster, store.clone());
    take_violations();

    let owned = store.owned_proxy(&Bytes(vec![5; 2000])).unwrap();
    let arg = executor.make_borrowed(&owned).unwrap();
    let fut = executor.submit::<u64>(
        vec![arg],
        Box::new(|_, _| panic!("worker crashed mid-task")),
    );
    assert!(matches!(fut.result(), Err(Error::Task(_))));

    // The borrow must have been released by the completion callback, so a
    // retry (the engine-rerun model) can mut-borrow and proceed.
    let mut ok = false;
    for _ in 0..100 {
        if owned.mut_borrow().is_ok() {
            ok = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(ok, "borrow leaked across a task panic");

    let retry_arg = executor.make_borrowed(&owned).unwrap();
    let retry = executor.submit::<u64>(
        vec![retry_arg],
        Box::new(|_, args| {
            let b: Bytes = args[0].get()?;
            Ok((b.0.len() as u64).to_bytes())
        }),
    );
    assert_eq!(retry.result().unwrap(), 2000);
    assert_eq!(take_violations(), 0);
}

#[test]
fn lease_expiry_mid_workflow_is_a_clean_not_found() {
    let store = Store::memory("lease-race");
    let lease = LeaseLifetime::new(Duration::from_millis(60));
    let p = store
        .proxy_with_lifetime(&Bytes(vec![1; 100]), &lease)
        .unwrap();
    let wire = p.to_bytes();
    // Consumer arrives after expiry.
    std::thread::sleep(Duration::from_millis(160));
    assert!(lease.done());
    let late: Proxy<Bytes> = Proxy::from_bytes(&wire).unwrap();
    assert!(matches!(late.resolve(), Err(Error::NotFound(_))));
}

#[test]
fn wait_get_across_server_clients_respects_timeout_under_load() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    // Saturate with a few blocked waiters, then check timeouts still fire.
    let addr = server.addr;
    let waiters: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let c = KvClient::connect(addr).unwrap();
                let t0 = std::time::Instant::now();
                let r = c
                    .wait_get(&format!("never-{i}"), Some(Duration::from_millis(80)))
                    .unwrap();
                (r, t0.elapsed())
            })
        })
        .collect();
    for w in waiters {
        let (r, dt) = w.join().unwrap();
        assert!(r.is_none());
        assert!(dt >= Duration::from_millis(80));
        assert!(dt < Duration::from_secs(5));
    }
}

#[test]
fn owner_dropped_while_task_holds_borrow_defers_eviction() {
    // The documented violation path: owner dies while a task reads.
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: 1,
        ..Default::default()
    }));
    let store = Store::memory("viol");
    let executor = StoreExecutor::new(cluster, store.clone());
    take_violations();

    let owned = store.owned_proxy(&Bytes(vec![1; 512])).unwrap();
    let key = owned.key().to_string();
    let arg = executor.make_borrowed(&owned).unwrap();
    let fut = executor.submit::<u64>(
        vec![arg],
        Box::new(|_, args| {
            std::thread::sleep(Duration::from_millis(80));
            let b: Bytes = args[0].get()?;
            Ok((b.0.len() as u64).to_bytes())
        }),
    );
    drop(owned); // violation: task still reading
    assert_eq!(take_violations(), 1);
    assert!(store.exists(&key).unwrap(), "eviction must be deferred");
    assert_eq!(fut.result().unwrap(), 512, "reader completes safely");
    // After release, the deferred eviction lands.
    let mut gone = false;
    for _ in 0..100 {
        if !store.exists(&key).unwrap() {
            gone = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(gone, "deferred eviction never happened");
}

#[test]
fn executor_value_args_survive_store_death() {
    // Inline (Value) args must not depend on the store at all.
    let mut server = ServerBuilder::new().spawn_kv().unwrap();
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: 1,
        ..Default::default()
    }));
    let store = Store::new(
        "dies",
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    );
    let executor = StoreExecutor::new(cluster, store);
    let arg = executor.make_arg(&42u64).unwrap();
    assert!(matches!(arg, TaskArg::Value(_)));
    server.shutdown();
    drop(server);
    let fut = executor.submit::<u64>(
        vec![arg],
        Box::new(|_, args| {
            let x: u64 = args[0].get()?;
            Ok((x + 1).to_bytes())
        }),
    );
    assert_eq!(fut.result().unwrap(), 43);
}
