//! Application-level integration: the three paper applications end to end
//! on small workloads, exercising the compiled PJRT artifacts from worker
//! threads. Requires `make artifacts`.

use std::sync::Arc;
use std::time::Duration;

use proxystore::apps::{ddmd, genomes, membench, mof, streambench};
use proxystore::runtime::{default_artifacts_dir, ModelRegistry};
use proxystore::workflow::DataMode;

fn registry() -> Arc<ModelRegistry> {
    ModelRegistry::load(default_artifacts_dir())
        .expect("run `make artifacts` before `cargo test`")
}

#[test]
fn genomes_all_modes_agree_and_proxyfuture_wins() {
    let cfg = genomes::GenomesConfig {
        individuals: 16,
        snps_per_chunk: 400,
        chunks: 4,
        groups: 2,
        task_overhead: Duration::from_millis(40),
        compute_floor: Duration::from_millis(20),
        seed: 77,
    };
    let want = genomes::run_reference(&cfg);
    let (base, f_base) = genomes::run(&cfg, DataMode::NoProxy).unwrap();
    let (pf, f_pf) = genomes::run(&cfg, DataMode::ProxyFuture).unwrap();
    assert_eq!(f_base, want);
    assert_eq!(f_pf, want);
    assert!(
        pf.makespan < base.makespan,
        "pipelining must win: {:.3} vs {:.3}",
        pf.makespan,
        base.makespan
    );
}

#[test]
fn ddmd_end_to_end_with_training() {
    let reg = registry();
    let cfg = ddmd::DdmdConfig {
        rounds: 5,
        initial_batch: 2,
        batch_growth: 2,
        train: true,
        ..Default::default()
    };
    let report = ddmd::run_proxystream(&cfg, &reg).unwrap();
    assert_eq!(report.rounds.len(), 5);
    assert!(report.model_updates >= 1, "trainer must deliver weights");
    assert!(report.mean_rtt > 0.0);
    // Batch sizes grow as configured.
    assert_eq!(report.rounds[0].batch, 2);
    assert_eq!(report.rounds[4].batch, 10);
}

#[test]
fn mof_ownership_cleans_up_against_live_registry() {
    let reg = registry();
    let cfg = mof::MofConfig {
        rounds: 2,
        generators: 2,
        top_k: 1,
        ..Default::default()
    };
    let d = mof::run(&cfg, &reg, mof::MemoryMode::Default).unwrap();
    let o = mof::run(&cfg, &reg, mof::MemoryMode::Ownership).unwrap();
    assert_eq!(d.rounds, 2);
    assert!(o.series.final_active() < d.series.final_active());
}

#[test]
fn streambench_smoke_all_modes() {
    let cfg = streambench::StreamBenchConfig {
        workers: 3,
        data_size: 100_000,
        task_time: Duration::from_millis(30),
        items: 6,
        dispatcher_bw: 1.0e9,
        broker_instances: 1,
        seed: 3,
    };
    for mode in streambench::StreamMode::all() {
        let r = streambench::run(&cfg, mode).unwrap();
        assert_eq!(r.items, 6, "{mode:?}");
    }
}

#[test]
fn membench_smoke_checksums_match() {
    let cfg = membench::MemBenchConfig {
        rounds: 1,
        mappers: 2,
        map_input: 200_000,
        map_output: 20_000,
        task_sleep: Duration::from_millis(10),
        seed: 4,
    };
    let a = membench::run(&cfg, membench::MemMode::NoProxy).unwrap();
    let b = membench::run(&cfg, membench::MemMode::Ownership).unwrap();
    assert_eq!(a.checksum, b.checksum);
}

#[test]
fn pjrt_concurrent_execution_from_many_workers() {
    // The registry is shared across threads; executables must be reusable
    // concurrently (the persistent-actor + trainer topology).
    let reg = registry();
    let d = reg.geometry("feature_dim").unwrap() as usize;
    let hs: Vec<_> = (0..4)
        .map(|i| {
            let reg = reg.clone();
            std::thread::spawn(move || {
                let x = vec![0.01 * (i as f32 + 1.0); d];
                let out = reg
                    .execute_with_bank("encode_b1", &[("x", &x)])
                    .unwrap();
                out[0].iter().map(|v| *v as f64).sum::<f64>()
            })
        })
        .collect();
    let sums: Vec<f64> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    // Different inputs give different embeddings; all finite.
    assert!(sums.iter().all(|s| s.is_finite()));
    assert!(sums.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9));
}
