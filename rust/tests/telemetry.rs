//! End-to-end telemetry plane: wire-level trace propagation through a
//! real TCP sharded fabric, whole-process registry coverage during an
//! elastic rebalance, and the remote snapshot op.
//!
//! The registry is process-global and these tests run in parallel
//! threads of one binary, so every assertion is a non-zero / superset
//! check scoped to this test's own trace id or key space — never an
//! exact global count.

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::codec::Bytes;
use proxystore::kv::{KvClient, KvServer};
use proxystore::metrics::telemetry;
use proxystore::metrics::{ClusterSnapshot, SpanNode};
use proxystore::net::{http_get, ServerBuilder};
use proxystore::prelude::Store;
use proxystore::shard::{ElasticShards, ShardMembers, ShardedConnector};
use proxystore::store::{
    Connector, MemoryConnector, TcpKvConnector, ThrottledConnector,
};

/// N live TCP KV servers and connectors onto them. The servers must stay
/// alive for the duration of the test — return them alongside.
fn tcp_backends(n: usize) -> (Vec<KvServer>, Vec<Arc<dyn Connector>>) {
    let mut servers = Vec::with_capacity(n);
    let mut conns: Vec<Arc<dyn Connector>> = Vec::with_capacity(n);
    for _ in 0..n {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        conns.push(Arc::new(TcpKvConnector::connect(server.addr).unwrap()));
        servers.push(server);
    }
    (servers, conns)
}

#[test]
fn trace_ids_propagate_across_a_tcp_sharded_get() {
    let (_servers, conns) = tcp_backends(2);
    let fabric = Arc::new(ShardedConnector::new(conns, 1, 0).unwrap());
    let store = Store::new("trace-itest", fabric);

    let guard = telemetry::start_trace("itest-traced-get");
    let trace_id = guard.ctx().trace_id;

    let key = store.put(&Bytes(vec![9u8; 128])).unwrap();
    let got: Option<Bytes> = store.get(&key).unwrap();
    assert_eq!(got.unwrap().0.len(), 128);
    drop(guard);

    let snap = telemetry::snapshot();
    let ours: Vec<_> =
        snap.events.iter().filter(|e| e.trace_id == trace_id).collect();
    let client_spans: Vec<_> =
        ours.iter().filter(|e| e.subsystem == "kv.client").collect();
    let server_spans: Vec<_> =
        ours.iter().filter(|e| e.subsystem == "kv.server").collect();

    // One put + one get, each with a client half and a server half.
    assert!(
        client_spans.len() >= 2,
        "expected client spans for put+get, got {ours:?}"
    );
    assert!(
        server_spans.len() >= 2,
        "expected server spans for put+get, got {ours:?}"
    );
    // Every server span is parented on a span the client emitted: the id
    // crossed the wire inside the Traced envelope, not via shared memory.
    for s in &server_spans {
        assert!(
            client_spans.iter().any(|c| c.span_id == s.parent_span),
            "server span {s:?} has no client parent among {client_spans:?}"
        );
    }
    // Op names survive the envelope.
    assert!(server_spans.iter().any(|s| s.name == "set"));
    assert!(server_spans.iter().any(|s| s.name == "get"));
}

#[test]
fn rebalance_over_tcp_reports_from_every_layer() {
    let (_servers, conns) = tcp_backends(3);
    let mut conns = conns.into_iter();
    let members: ShardMembers =
        (0..2).map(|id| (id, conns.next().unwrap())).collect();
    let elastic =
        ElasticShards::new("telemetry-itest", members, 1, 16).unwrap();
    let store = Store::new("telemetry-itest", Arc::new(elastic.clone()));

    let objs: Vec<Bytes> =
        (0..64).map(|i| Bytes(vec![(i % 251) as u8; 256])).collect();
    let keys = store.put_many(&objs).unwrap();

    // Arm a watch before the membership change, fulfil it after: the
    // watch plane participates in the rebalance (re-arm on epoch flip).
    let armed = store.watch_async::<Bytes>("telemetry-itest-sentinel");

    elastic.add_shard(2, conns.next().unwrap()).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));

    store
        .put_at("telemetry-itest-sentinel", &Bytes(vec![1u8; 8]))
        .unwrap();
    assert!(armed.wait().unwrap().is_some());

    for key in &keys {
        assert!(store.get::<Bytes>(key).unwrap().is_some());
    }

    // One snapshot, whole process: the acceptance gate for the unified
    // plane is that every fabric this scenario touched shows up.
    let snap = telemetry::snapshot();
    let subs = snap.active_subsystems();
    for expected in ["kv.client", "kv.server", "shard", "store", "watch"] {
        assert!(
            subs.iter().any(|s| s == expected),
            "subsystem {expected} silent; active: {subs:?}"
        );
    }
    assert!(
        subs.len() >= 5,
        "expected >=5 active subsystems, got {subs:?}"
    );
    // The elastic daemon folds its migration counters into the registry.
    assert!(
        snap.counter("rebalance.keys_migrated") > 0,
        "migration ran but rebalance.keys_migrated is zero"
    );
    // The wake actually crossed the push plane.
    assert!(snap.counter("watch.fires") > 0);
}

#[test]
fn telemetry_snapshot_crosses_the_wire() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = KvClient::connect(server.addr).unwrap();

    client.set("wire-snap-key", Bytes(vec![3u8; 64])).unwrap();
    assert!(client.get("wire-snap-key").unwrap().is_some());

    let remote = client.telemetry().unwrap();
    // The snapshot decoded from the wire reflects the server that served
    // these very ops (same process, so counters are non-zero and the
    // histogram saw our requests).
    assert!(remote.counter("kv.server.frames_in") >= 2);
    assert!(remote.counter("kv.server.frames_out") >= 2);
    let op_us = remote
        .histogram("kv.server.op_us")
        .expect("server op histogram present");
    assert!(op_us.count >= 2);
    // Encode → decode is lossless for the rendered view too.
    assert!(!remote.render().is_empty());
}

/// Structural JSON check without a parser dependency: every bracket
/// balances, tracked with string/escape awareness.
fn assert_json_balanced(s: &str) {
    let mut stack: Vec<char> = Vec::new();
    let mut in_string = false;
    let mut escaped = false;
    for c in s.chars() {
        if in_string {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_string = false;
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' | '[' => stack.push(c),
            '}' => assert_eq!(stack.pop(), Some('{'), "unbalanced }}"),
            ']' => assert_eq!(stack.pop(), Some('['), "unbalanced ]"),
            _ => {}
        }
    }
    assert!(!in_string, "unterminated string");
    assert!(stack.is_empty(), "unclosed brackets: {stack:?}");
}

#[test]
fn cluster_scrape_assembles_cross_process_span_trees() {
    let (_servers, conns) = tcp_backends(2);
    let fabric = Arc::new(ShardedConnector::new(conns, 1, 0).unwrap());
    let store = Store::new("spantree-itest", fabric.clone());

    let guard = telemetry::start_trace("spantree-itest");
    let trace_id = guard.ctx().trace_id;
    let root_span = guard.ctx().span_id;
    // Enough individually-traced ops that both shards participate.
    let keys: Vec<String> = (0..8)
        .map(|i| store.put(&Bytes(vec![i as u8; 64])).unwrap())
        .collect();
    for key in &keys {
        assert!(store.get::<Bytes>(key).unwrap().is_some());
    }
    drop(guard);

    // Fan the Telemetry op across the fabric over the wire and merge
    // with the local registry.
    let cs = ClusterSnapshot::scrape_sharded(&fabric);
    assert!(cs.errors.is_empty(), "scrape errors: {:?}", cs.errors);
    assert!(cs.nodes.len() >= 3, "local + 2 shards, got {}", cs.nodes.len());

    // One tree per trace: the start_trace root span at the top, a
    // client span per op under it, each parenting the server half that
    // was stamped on the other side of the TCP connection.
    let trees = cs.span_trees_for(trace_id);
    assert_eq!(trees.len(), 1, "one root expected, got {}", trees.len());
    let root = &trees[0];
    assert_eq!(root.event.span_id, root_span);
    assert_eq!(root.event.subsystem, "trace");
    let clients: Vec<&SpanNode> = root
        .children
        .iter()
        .filter(|c| c.event.subsystem == "kv.client")
        .collect();
    assert!(
        clients.len() >= 16,
        "8 puts + 8 gets should each leave a client span, got {}",
        clients.len()
    );
    for c in &clients {
        assert!(
            c.event.dur_us > 0,
            "client span carries its round-trip duration: {:?}",
            c.event
        );
        let server_halves = c
            .children
            .iter()
            .filter(|s| s.event.subsystem == "kv.server")
            .count();
        assert_eq!(
            server_halves, 1,
            "client span {:x} should parent exactly its server half",
            c.event.span_id
        );
    }

    // The Chrome trace-viewer export covers every span in the tree and
    // is structurally valid JSON.
    let json = cs.chrome_trace();
    assert_json_balanced(&json);
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.contains("\"ph\":\"M\""), "process_name metadata missing");
    let complete = json.matches("\"ph\":\"X\"").count();
    let tree_spans: usize = trees.iter().map(SpanNode::size).sum();
    assert!(
        complete >= tree_spans,
        "{complete} complete events < {tree_spans} tree spans"
    );
    for name in ["set", "get"] {
        assert!(
            json.contains(&format!("\"name\":\"{name}\"")),
            "span name {name:?} missing from export"
        );
    }
}

#[test]
fn admin_endpoint_serves_prometheus_exposition() {
    let server = ServerBuilder::new()
        .admin_addr("127.0.0.1:0".parse().unwrap())
        .spawn_kv()
        .unwrap();
    let client = KvClient::connect(server.addr).unwrap();
    client.set("admin-itest", Bytes(vec![1u8; 32])).unwrap();
    assert!(client.get("admin-itest").unwrap().is_some());

    let admin = server.admin_addr().expect("admin plane spawned");
    let (status, body) = http_get(admin, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");

    let (status, body) = http_get(admin, "/metrics").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("# TYPE"), "no TYPE headers: {body:?}");
    // Valid exposition: every sample line is `name[{labels}] value`
    // with a sanitized name and a numeric value.
    let mut samples = 0usize;
    for line in body.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("bad sample line {line:?}"));
        let name = name_part.split('{').next().unwrap();
        let mut chars = name.chars();
        let first = chars.next().unwrap_or(' ');
        assert!(
            first.is_ascii_alphabetic() || first == '_' || first == ':',
            "bad metric name {name:?} in {line:?}"
        );
        assert!(
            chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "unsanitized metric name {name:?} in {line:?}"
        );
        assert!(
            value.parse::<f64>().is_ok(),
            "non-numeric sample value in {line:?}"
        );
        samples += 1;
    }
    assert!(samples > 0, "empty exposition");
    // The plane reflects the very traffic this test just sent.
    assert!(
        body.contains("kv_server_frames_in"),
        "server family missing from exposition"
    );

    // The rest of the admin surface answers on the same connection
    // semantics: trace export is valid JSON, slow log and conns render,
    // unknown routes 404, non-GET methods are rejected by routing.
    let (status, trace) = http_get(admin, "/trace").unwrap();
    assert_eq!(status, 200);
    assert_json_balanced(&trace);
    assert!(trace.starts_with("{\"traceEvents\":["));
    let (status, _) = http_get(admin, "/slow").unwrap();
    assert_eq!(status, 200);
    let (status, conns) = http_get(admin, "/conns").unwrap();
    assert_eq!(status, 200);
    assert!(conns.contains("kv.connections"), "conns: {conns:?}");
    let (status, _) = http_get(admin, "/nope").unwrap();
    assert_eq!(status, 404);
    // Query strings route to the bare path.
    let (status, _) = http_get(admin, "/healthz?verbose=1").unwrap();
    assert_eq!(status, 200);
}

#[test]
fn readyz_flips_not_ready_while_elastic_migration_drains() {
    // A standalone admin plane: the readiness registry is
    // process-global, so any endpoint reflects the elastic probe.
    let mut admin_pool = proxystore::net::http::spawn_admin(
        "127.0.0.1:0".parse().unwrap(),
        "readyz-itest",
        Arc::new(|| 0),
    )
    .unwrap();
    let admin = admin_pool.addr;
    let probe = "elastic.readyz-itest";

    let members: ShardMembers =
        (0..2).map(|id| (id, MemoryConnector::new())).collect();
    let elastic = ElasticShards::new("readyz-itest", members, 1, 16).unwrap();
    let store = Store::new("readyz-itest", Arc::new(elastic.clone()));

    // Ready while the membership is stable.
    let (_, body) = http_get(admin, "/readyz").unwrap();
    assert!(!body.contains(probe), "ready fabric blocks readyz: {body:?}");

    // Data worth migrating, then a membership change onto a throttled
    // backend: the ~1/3 of keys that remap now take real wall-clock to
    // move, holding the drain window open while we scrape.
    let objs: Vec<Bytes> =
        (0..256).map(|i| Bytes(vec![(i % 251) as u8; 4096])).collect();
    store.put_many(&objs).unwrap();
    let slow_backend = ThrottledConnector::wrap(
        MemoryConnector::new(),
        Duration::from_millis(20),
        200_000.0,
    );
    elastic.add_shard(2, slow_backend).unwrap();

    // The probe reports not-ready for the whole drain; poll until the
    // endpoint shows it (immediately, in practice).
    let deadline = Instant::now() + Duration::from_secs(10);
    let saw_not_ready = loop {
        let (status, body) = http_get(admin, "/readyz").unwrap();
        if status == 503 && body.contains(probe) {
            break true;
        }
        if !elastic.migrating() || Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(1));
    };
    assert!(
        saw_not_ready,
        "migration drained without /readyz ever showing {probe}"
    );

    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));
    // Flipped back: this fabric no longer blocks readiness. (Parallel
    // tests may hold their own probes, so assert on ours, and on the
    // full 200 only when nothing else is draining.)
    let (status, body) = http_get(admin, "/readyz").unwrap();
    assert!(
        !body.contains(probe),
        "drained fabric still blocks readyz: {body:?}"
    );
    if status == 200 {
        assert_eq!(body, "ready\n");
    }

    // Keys survived the throttled migration.
    for (i, key) in store.put_many(&objs[..4]).unwrap().iter().enumerate() {
        assert!(store.get::<Bytes>(key).unwrap().is_some(), "key {i} lost");
    }
    admin_pool.shutdown();
}
