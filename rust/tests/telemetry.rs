//! End-to-end telemetry plane: wire-level trace propagation through a
//! real TCP sharded fabric, whole-process registry coverage during an
//! elastic rebalance, and the remote snapshot op.
//!
//! The registry is process-global and these tests run in parallel
//! threads of one binary, so every assertion is a non-zero / superset
//! check scoped to this test's own trace id or key space — never an
//! exact global count.

use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::Bytes;
use proxystore::kv::{KvClient, KvServer};
use proxystore::net::ServerBuilder;
use proxystore::metrics::telemetry;
use proxystore::prelude::Store;
use proxystore::shard::{ElasticShards, ShardMembers, ShardedConnector};
use proxystore::store::{Connector, TcpKvConnector};

/// N live TCP KV servers and connectors onto them. The servers must stay
/// alive for the duration of the test — return them alongside.
fn tcp_backends(n: usize) -> (Vec<KvServer>, Vec<Arc<dyn Connector>>) {
    let mut servers = Vec::with_capacity(n);
    let mut conns: Vec<Arc<dyn Connector>> = Vec::with_capacity(n);
    for _ in 0..n {
        let server = ServerBuilder::new().spawn_kv().unwrap();
        conns.push(Arc::new(TcpKvConnector::connect(server.addr).unwrap()));
        servers.push(server);
    }
    (servers, conns)
}

#[test]
fn trace_ids_propagate_across_a_tcp_sharded_get() {
    let (_servers, conns) = tcp_backends(2);
    let fabric = Arc::new(ShardedConnector::new(conns, 1, 0).unwrap());
    let store = Store::new("trace-itest", fabric);

    let guard = telemetry::start_trace("itest-traced-get");
    let trace_id = guard.ctx().trace_id;

    let key = store.put(&Bytes(vec![9u8; 128])).unwrap();
    let got: Option<Bytes> = store.get(&key).unwrap();
    assert_eq!(got.unwrap().0.len(), 128);
    drop(guard);

    let snap = telemetry::snapshot();
    let ours: Vec<_> =
        snap.events.iter().filter(|e| e.trace_id == trace_id).collect();
    let client_spans: Vec<_> =
        ours.iter().filter(|e| e.subsystem == "kv.client").collect();
    let server_spans: Vec<_> =
        ours.iter().filter(|e| e.subsystem == "kv.server").collect();

    // One put + one get, each with a client half and a server half.
    assert!(
        client_spans.len() >= 2,
        "expected client spans for put+get, got {ours:?}"
    );
    assert!(
        server_spans.len() >= 2,
        "expected server spans for put+get, got {ours:?}"
    );
    // Every server span is parented on a span the client emitted: the id
    // crossed the wire inside the Traced envelope, not via shared memory.
    for s in &server_spans {
        assert!(
            client_spans.iter().any(|c| c.span_id == s.parent_span),
            "server span {s:?} has no client parent among {client_spans:?}"
        );
    }
    // Op names survive the envelope.
    assert!(server_spans.iter().any(|s| s.name == "set"));
    assert!(server_spans.iter().any(|s| s.name == "get"));
}

#[test]
fn rebalance_over_tcp_reports_from_every_layer() {
    let (_servers, conns) = tcp_backends(3);
    let mut conns = conns.into_iter();
    let members: ShardMembers =
        (0..2).map(|id| (id, conns.next().unwrap())).collect();
    let elastic =
        ElasticShards::new("telemetry-itest", members, 1, 16).unwrap();
    let store = Store::new("telemetry-itest", Arc::new(elastic.clone()));

    let objs: Vec<Bytes> =
        (0..64).map(|i| Bytes(vec![(i % 251) as u8; 256])).collect();
    let keys = store.put_many(&objs).unwrap();

    // Arm a watch before the membership change, fulfil it after: the
    // watch plane participates in the rebalance (re-arm on epoch flip).
    let armed = store.watch_async::<Bytes>("telemetry-itest-sentinel");

    elastic.add_shard(2, conns.next().unwrap()).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(60))));

    store
        .put_at("telemetry-itest-sentinel", &Bytes(vec![1u8; 8]))
        .unwrap();
    assert!(armed.wait().unwrap().is_some());

    for key in &keys {
        assert!(store.get::<Bytes>(key).unwrap().is_some());
    }

    // One snapshot, whole process: the acceptance gate for the unified
    // plane is that every fabric this scenario touched shows up.
    let snap = telemetry::snapshot();
    let subs = snap.active_subsystems();
    for expected in ["kv.client", "kv.server", "shard", "store", "watch"] {
        assert!(
            subs.iter().any(|s| s == expected),
            "subsystem {expected} silent; active: {subs:?}"
        );
    }
    assert!(
        subs.len() >= 5,
        "expected >=5 active subsystems, got {subs:?}"
    );
    // The elastic daemon folds its migration counters into the registry.
    assert!(
        snap.counter("rebalance.keys_migrated") > 0,
        "migration ran but rebalance.keys_migrated is zero"
    );
    // The wake actually crossed the push plane.
    assert!(snap.counter("watch.fires") > 0);
}

#[test]
fn telemetry_snapshot_crosses_the_wire() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = KvClient::connect(server.addr).unwrap();

    client.set("wire-snap-key", Bytes(vec![3u8; 64])).unwrap();
    assert!(client.get("wire-snap-key").unwrap().is_some());

    let remote = client.telemetry().unwrap();
    // The snapshot decoded from the wire reflects the server that served
    // these very ops (same process, so counters are non-zero and the
    // histogram saw our requests).
    assert!(remote.counter("kv.server.frames_in") >= 2);
    assert!(remote.counter("kv.server.frames_out") >= 2);
    let op_us = remote
        .histogram("kv.server.op_us")
        .expect("server op histogram present");
    assert!(op_us.count >= 2);
    // Encode → decode is lossless for the rendered view too.
    assert!(!remote.render().is_empty());
}
