//! End-to-end durability plane: a hard-killed KV shard restarts on the
//! same port, recovers its acked state from snapshot + WAL replay, and
//! rejoins a live elastic fabric with zero read misses under concurrent
//! load; a broker restart preserves topic contents and committed
//! offsets; a torn WAL tail is truncated, not fatal.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use proxystore::codec::Bytes;
use proxystore::kv::KvClient;
use proxystore::persist::{DurabilityOptions, FsyncPolicy};
use proxystore::prelude::Store;
use proxystore::shard::{ElasticShards, ShardMembers};
use proxystore::store::{Connector, TcpKvConnector};
use proxystore::testing::fail::RestartableServer;
use proxystore::testing::load::ReadProbe;

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "proxystore-itest-persist-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The tentpole acceptance test: kill a durable TCP shard out of a live
/// elastic fabric, restart it on the same address, splice it back in
/// with [`ElasticShards::rejoin_shard`], and prove that concurrent
/// readers never missed — replica fallback covers the outage, recovery
/// covers the state.
#[test]
fn killed_kv_shard_recovers_and_rejoins_elastic_fabric() {
    let dir = scratch_dir("rejoin");
    // fsync per op: everything the store acked must survive the kill.
    let opts = DurabilityOptions::new(&dir).fsync(FsyncPolicy::EveryOp);
    let mut victim = RestartableServer::kv(opts).unwrap();
    let peers: Vec<_> = (0..2)
        .map(|_| {
            proxystore::net::ServerBuilder::new().spawn_kv().unwrap()
        })
        .collect();

    let mut members: ShardMembers = vec![(
        0,
        Arc::new(TcpKvConnector::connect(victim.addr()).unwrap())
            as Arc<dyn Connector>,
    )];
    for (i, p) in peers.iter().enumerate() {
        members.push((
            i + 1,
            Arc::new(TcpKvConnector::connect(p.addr).unwrap())
                as Arc<dyn Connector>,
        ));
    }
    // replicas=2: every object lives on two shards, so reads survive the
    // window where the victim is down.
    let elastic =
        ElasticShards::new("persist-rejoin", members, 2, 64).unwrap();
    let store = Store::new("persist", Arc::new(elastic.clone()));

    let objs: Vec<Bytes> =
        (0..96).map(|i| Bytes(vec![i as u8; 256])).collect();
    let keys = store.put_many(&objs).unwrap();

    // How many objects the victim actually holds (its primary + replica
    // share); recovery must bring back exactly this many.
    let resident_before = {
        let probe = KvClient::connect(victim.addr()).unwrap();
        let (resident, _, _) = probe.stats().unwrap();
        resident
    };
    assert!(resident_before > 0, "victim holds no keys; test is vacuous");

    // Readers hammer the full key set through kill, restart, and rejoin.
    let probe = ReadProbe::spawn(&store, &keys, 3);
    std::thread::sleep(Duration::from_millis(30));

    victim.kill();
    // The fabric rides replica fallback while the shard is down.
    std::thread::sleep(Duration::from_millis(60));
    victim.restart().unwrap();

    let stats = victim
        .kv_state()
        .expect("restarted server is a kv server")
        .recovery_stats()
        .expect("restarted server must be durable");
    assert_eq!(
        stats.replayed_records, resident_before,
        "recovery must replay exactly the acked mutations"
    );
    assert_eq!(stats.truncated_records, 0, "clean kill, no torn tail");
    let (resident_after, _, _) =
        KvClient::connect(victim.addr()).unwrap().stats().unwrap();
    assert_eq!(resident_after, resident_before);

    // Splice the recovered shard back in under its old ring id: empty
    // placement delta, immediate epoch flip, no migration.
    let fresh = Arc::new(TcpKvConnector::connect(victim.addr()).unwrap())
        as Arc<dyn Connector>;
    elastic.rejoin_shard(0, fresh).unwrap();
    assert!(elastic.wait_quiescent(Some(Duration::from_secs(30))));
    assert_eq!(elastic.shard_ids(), vec![0, 1, 2]);

    std::thread::sleep(Duration::from_millis(30));
    let (reads, misses) = probe.finish();
    assert!(reads > 0, "probe never read");
    assert_eq!(
        misses, 0,
        "a crash-restart-rejoin cycle must not surface a single miss"
    );

    // Full key set still resolves with intact payloads, and writes land
    // on the recovered shard again.
    for (i, key) in keys.iter().enumerate() {
        let got: Option<Bytes> = store.get(key).unwrap();
        assert_eq!(got.map(|b| b.0), Some(vec![i as u8; 256]));
    }
    store.put_at("post-rejoin", &Bytes(vec![9u8; 32])).unwrap();
    assert!(store.get::<Bytes>("post-rejoin").unwrap().is_some());

    let _ = std::fs::remove_dir_all(&dir);
}

/// Restarting a durable shard twice in a row keeps compounding state:
/// writes between incarnations replay on top of the earlier recovery.
#[test]
fn kv_restart_accumulates_across_incarnations() {
    let dir = scratch_dir("accumulate");
    let opts = DurabilityOptions::new(&dir)
        .fsync(FsyncPolicy::EveryOp)
        .snapshot_every_ops(8);
    let mut server = RestartableServer::kv(opts).unwrap();

    let put = |addr, tag: &str, n: usize| -> Vec<String> {
        let store =
            Store::new(tag, Arc::new(TcpKvConnector::connect(addr).unwrap()));
        store
            .put_many(
                &(0..n).map(|i| Bytes(vec![i as u8; 64])).collect::<Vec<_>>(),
            )
            .unwrap()
    };
    let first = put(server.addr(), "gen0", 20);
    server.kill();
    server.restart().unwrap();
    let second = put(server.addr(), "gen1", 20);
    server.kill();
    server.restart().unwrap();

    // Second recovery seeds from a snapshot (cadence 8 < 20 mutations)
    // and replays only the tail beyond it.
    let stats =
        server.kv_state().unwrap().recovery_stats().unwrap();
    assert!(
        stats.snapshot_seq.is_some(),
        "snapshot cadence of 8 must have produced a snapshot"
    );
    assert!(stats.replayed_records < 40, "snapshot must bound replay");

    let store = Store::new(
        "gen2",
        Arc::new(TcpKvConnector::connect(server.addr()).unwrap()),
    );
    for key in first.iter().chain(&second) {
        assert!(
            store.get::<Bytes>(key).unwrap().is_some(),
            "key {key} lost across double restart"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Broker crash-restart: topic contents, per-partition offsets, and
/// consumer-group committed offsets all survive.
#[test]
fn broker_restart_preserves_topics_and_commits() {
    let dir = scratch_dir("broker");
    let opts = DurabilityOptions::new(&dir).fsync(FsyncPolicy::EveryOp);
    let mut server = RestartableServer::broker(opts).unwrap();
    let client =
        proxystore::broker::BrokerClient::connect(server.addr()).unwrap();

    // Two partitions with distinct contents, plus a group commit.
    for i in 0..20u64 {
        let off = client
            .produce_to("events", (i % 2) as u32, Bytes(vec![i as u8; 48]))
            .unwrap();
        assert_eq!(off, i / 2, "offsets are dense per partition");
    }
    client.commit_part("grp", "events", 0, 7).unwrap();
    client.commit_part("grp", "events", 1, 3).unwrap();
    drop(client);

    server.kill();
    server.restart().unwrap();
    let stats =
        server.broker_state().unwrap().recovery_stats().unwrap();
    assert_eq!(stats.replayed_records, 20);

    let client =
        proxystore::broker::BrokerClient::connect(server.addr()).unwrap();
    for part in 0..2u32 {
        assert_eq!(client.end_offset_of("events", part).unwrap(), 10);
        let entries = client
            .fetch_from("events", part, 0, 32, Duration::ZERO)
            .unwrap();
        assert_eq!(entries.len(), 10);
        for (j, e) in entries.iter().enumerate() {
            assert_eq!(e.offset, j as u64);
            assert_eq!(
                e.payload.0,
                vec![(2 * j as u64 + part as u64) as u8; 48],
                "partition {part} entry {j} corrupted by recovery"
            );
        }
    }
    assert_eq!(client.committed_part("grp", "events", 0).unwrap(), 7);
    assert_eq!(client.committed_part("grp", "events", 1).unwrap(), 3);

    // New produces continue the recovered offset space densely.
    assert_eq!(
        client.produce_to("events", 0, Bytes(vec![0xEE; 8])).unwrap(),
        10
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A torn WAL tail (simulated half-written frame) is truncated on
/// restart: every fully-synced record survives, the damage is counted in
/// `recovery.truncated_records`, and the shard serves again.
#[test]
fn torn_wal_tail_is_truncated_not_fatal() {
    let dir = scratch_dir("torn");
    let opts = DurabilityOptions::new(&dir).fsync(FsyncPolicy::EveryOp);
    let mut server = RestartableServer::kv(opts).unwrap();
    let store = Store::new(
        "torn",
        Arc::new(TcpKvConnector::connect(server.addr()).unwrap()),
    );
    let keys = store
        .put_many(&(0..12).map(|i| Bytes(vec![i as u8; 64])).collect::<Vec<_>>())
        .unwrap();
    server.kill();

    // Simulate a crash mid-append: garbage half-frame at the log tail.
    let wal_dir = dir.join("kv").join("wal");
    let mut segments: Vec<_> = std::fs::read_dir(&wal_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "wal"))
        .collect();
    segments.sort();
    let tail = segments.last().expect("wal segment exists");
    use std::io::Write as _;
    std::fs::OpenOptions::new()
        .append(true)
        .open(tail)
        .unwrap()
        .write_all(&[0x55; 5])
        .unwrap();

    server.restart().unwrap();
    let stats =
        server.kv_state().unwrap().recovery_stats().unwrap();
    assert_eq!(stats.replayed_records, 12, "synced records survive");
    assert!(stats.truncated_records >= 1, "torn tail must be counted");

    let store = Store::new(
        "torn-after",
        Arc::new(TcpKvConnector::connect(server.addr()).unwrap()),
    );
    for key in &keys {
        assert!(store.get::<Bytes>(key).unwrap().is_some());
    }
    // The truncated log accepts fresh appends.
    store.put_at("after-tear", &Bytes(vec![1u8; 16])).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
