//! Integration tests for the nonblocking op-submission data plane: the
//! pipelined TCP KV client, `Pending` completion semantics end to end,
//! the async `Store` surface, and in-flight overlap through the shard
//! fabric and latency injection.

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::kv::{KvClient, KvServer, Request};
use proxystore::net::ServerBuilder;
use proxystore::ops::{Op, OpResult};
use proxystore::prelude::{Proxy, Store};
use proxystore::shard::ShardedConnector;
use proxystore::store::{Connector, MemoryConnector, TcpKvConnector};
use proxystore::testing::fail::FlakyConnector;

#[test]
fn pipelined_window_roundtrips_over_tcp() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = KvClient::connect(server.addr).unwrap();
    // A whole window in flight before the first wait: one shared stream.
    let puts: Vec<_> = (0..64)
        .map(|i| {
            client.submit_op(Op::Put {
                key: format!("w-{i}"),
                data: vec![i as u8; 128],
            })
        })
        .collect();
    for p in puts {
        p.wait().unwrap().into_unit().unwrap();
    }
    let gets: Vec<_> = (0..64)
        .map(|i| client.submit_op(Op::Get { key: format!("w-{i}") }))
        .collect();
    for (i, g) in gets.into_iter().enumerate() {
        assert_eq!(
            g.wait().unwrap().into_value().unwrap().map(|b| b.to_vec()),
            Some(vec![i as u8; 128])
        );
    }
    // Typed batched ops share the same pipe.
    let bools = client
        .submit_op(Op::ExistsMany {
            keys: vec!["w-0".into(), "nope".into(), "w-63".into()],
        })
        .wait()
        .unwrap()
        .into_bools()
        .unwrap();
    assert_eq!(bools, vec![true, false, true]);
}

#[test]
fn submission_order_is_execution_order() {
    // FIFO pipelining means a get submitted after a put of the same key
    // (on the same connection) must observe it — no waits in between.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = KvClient::connect(server.addr).unwrap();
    let mut pairs = Vec::new();
    for round in 0..16 {
        let put = client.submit_op(Op::Put {
            key: "hot".into(),
            data: vec![round as u8],
        });
        let get = client.submit_op(Op::Get { key: "hot".into() });
        pairs.push((round as u8, put, get));
    }
    for (round, put, get) in pairs {
        put.wait().unwrap().into_unit().unwrap();
        assert_eq!(
            get.wait().unwrap().into_value().unwrap().map(|b| b.to_vec()),
            Some(vec![round]),
            "get overtook its put in round {round}"
        );
    }
}

#[test]
fn pipelined_connection_death_mid_flight() {
    let mut server = ServerBuilder::new().spawn_kv().unwrap();
    let client = KvClient::connect(server.addr).unwrap();
    client.set("pre", Bytes(vec![1])).unwrap();
    // Park one op server-side so the stream is mid-flight, then kill the
    // server under the connection.
    let parked = client.submit(Request::WaitGet {
        key: "never".into(),
        timeout_ms: 30_000,
    });
    let queued = client.submit_op(Op::Get { key: "pre".into() });
    std::thread::sleep(Duration::from_millis(30));
    server.shutdown();
    // Every in-flight handle settles with an error — nothing hangs.
    assert!(parked.wait().is_err());
    assert!(queued.wait().is_err());
    // And the pipe stays dead-fast for later submissions.
    let t0 = Instant::now();
    assert!(client.submit_op(Op::Exists { key: "pre".into() }).wait().is_err());
    assert!(t0.elapsed() < Duration::from_secs(2));
}

#[test]
fn tcp_connector_submits_nonblocking() {
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let conn = TcpKvConnector::connect(server.addr).unwrap();
    assert!(conn.submits_nonblocking());
    let handles: Vec<_> = (0..32)
        .map(|i| {
            conn.submit(Op::Put { key: format!("c-{i}"), data: vec![i as u8] })
        })
        .collect();
    for h in handles {
        h.wait().unwrap().into_unit().unwrap();
    }
    assert_eq!(conn.len().unwrap(), 32);
    // Memory stays a blocking bridge (inline completion).
    let mem = MemoryConnector::new();
    assert!(!mem.submits_nonblocking());
    let h = mem.submit(Op::Put { key: "m".into(), data: vec![9] });
    assert!(h.is_complete());
    h.wait().unwrap().into_unit().unwrap();
}

#[test]
fn async_store_over_tcp_shard_fabric() {
    // The full stack: Store -> sharded fabric -> TCP backends, driven
    // through the async surface.
    let servers: Vec<KvServer> =
        (0..3).map(|_| ServerBuilder::new().spawn_kv().unwrap()).collect();
    let backends: Vec<Arc<dyn Connector>> = servers
        .iter()
        .map(|s| {
            Arc::new(TcpKvConnector::connect(s.addr).unwrap())
                as Arc<dyn Connector>
        })
        .collect();
    let router = Arc::new(ShardedConnector::new(backends, 1, 64).unwrap());
    let store = Store::new("async-fabric", router);

    let writes: Vec<_> =
        (0..48).map(|i| store.put_async(&format!("obj-{i}"))).collect();
    for w in &writes {
        w.wait().unwrap();
    }
    let reads: Vec<_> = writes
        .iter()
        .map(|w| store.get_async::<String>(w.key()))
        .collect();
    for (i, r) in reads.into_iter().enumerate() {
        assert_eq!(r.wait().unwrap(), Some(format!("obj-{i}")));
    }

    // proxy_async: the proxy resolves once the write settles.
    let (proxy, write) = store.proxy_async(&"late-bound".to_string());
    write.wait().unwrap();
    let shipped: Proxy<String> = Proxy::from_bytes(&proxy.to_bytes()).unwrap();
    assert_eq!(shipped.resolve().unwrap(), "late-bound");
}

#[test]
fn sharded_fan_out_overlaps_slow_backends() {
    // 4 shards, each 80ms slow: a batched get spanning all of them must
    // pay ~one delay (overlapped fan-out), not four (serialized).
    let flakies: Vec<Arc<FlakyConnector>> = (0..4)
        .map(|_| FlakyConnector::wrap(MemoryConnector::new()))
        .collect();
    let backends: Vec<Arc<dyn Connector>> = flakies
        .iter()
        .map(|f| f.clone() as Arc<dyn Connector>)
        .collect();
    let router = Arc::new(ShardedConnector::new(backends, 1, 64).unwrap());
    let items: Vec<(String, Vec<u8>)> =
        (0..64).map(|i| (format!("ov-{i}"), vec![i as u8])).collect();
    router.put_many(items.clone()).unwrap();
    for f in &flakies {
        f.set_latency(Duration::from_millis(80));
    }
    let keys: Vec<String> = items.iter().map(|(k, _)| k.clone()).collect();
    let t0 = Instant::now();
    let got = router.get_many(&keys).unwrap();
    let elapsed = t0.elapsed();
    assert!(got.iter().all(|b| b.is_some()));
    // 4 x 80ms serialized = 320ms; the bound leaves one extra wave of
    // slack for contention on the process-global pool from tests running
    // in parallel, while still proving the fan-out overlapped.
    assert!(
        elapsed < Duration::from_millis(240),
        "fan-out serialized the slow shards: {elapsed:?}"
    );
}

#[test]
fn pending_error_propagates_through_store() {
    let flaky = FlakyConnector::wrap(MemoryConnector::new());
    let store = Store::new("flaky-async", flaky.clone());
    flaky.set_down(true);
    let write = store.put_async(&1u64);
    assert!(write.wait().is_err());
    let read = store.get_async::<u64>("whatever");
    assert!(read.wait().is_err());
    flaky.set_down(false);
    let write = store.put_async(&2u64);
    write.wait().unwrap();
    assert_eq!(
        store.get_async::<u64>(write.key()).wait().unwrap(),
        Some(2)
    );
}

#[test]
fn mixed_submit_and_blocking_traffic_coexist() {
    // Blocking calls and submitted ops interleave on one pipelined
    // connection without corrupting FIFO matching.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let client = Arc::new(KvClient::connect(server.addr).unwrap());
    let hammers: Vec<_> = (0..3)
        .map(|t| {
            let c = client.clone();
            std::thread::spawn(move || {
                for i in 0..32 {
                    let key = format!("mix-{t}-{i}");
                    c.set(&key, Bytes(vec![t as u8, i as u8])).unwrap();
                    let h = c.submit_op(Op::Get { key: key.clone() });
                    assert_eq!(
                        h.wait()
                            .unwrap()
                            .into_value()
                            .unwrap()
                            .map(|b| b.to_vec()),
                        Some(vec![t as u8, i as u8])
                    );
                }
            })
        })
        .collect();
    for h in hammers {
        h.join().unwrap();
    }
    let (keys, _, _) = client.stats().unwrap();
    assert_eq!(keys, 96);
}

#[test]
fn op_result_shape_mismatch_is_an_error() {
    let mem = MemoryConnector::new();
    let res = mem
        .submit(Op::Get { key: "missing".into() })
        .wait()
        .unwrap();
    assert!(matches!(res, OpResult::Value(None)));
    // Taking the wrong shape reports, never panics.
    assert!(mem
        .submit(Op::Get { key: "missing".into() })
        .wait()
        .unwrap()
        .into_bools()
        .is_err());
}
