//! Property-based invariant tests over the coordinator substrates,
//! using the in-tree `testing` framework (DESIGN.md §6).

use std::collections::HashMap;
use std::sync::Arc;

use proxystore::broker::BrokerState;
use proxystore::codec::{Bytes, Decode, Encode, F32s};
use proxystore::engine::{ClusterConfig, LocalCluster};
use proxystore::kv::KvState;
use proxystore::ownership::{take_violations, StoreOwnedExt};
use proxystore::prelude::Store;
use proxystore::stream::{
    BatchAggregator, EmbeddedLogPublisher, EmbeddedLogSubscriber, Metadata,
    Plugin, StreamConsumer, StreamProducer,
};
use proxystore::testing::{forall, gens, Gen};

// ---------------------------------------------------------------------
// Codec: decode(encode(x)) == x for nested composite data.
// ---------------------------------------------------------------------

#[test]
fn prop_codec_roundtrip_nested() {
    let gen = gens::vec(
        gens::pair(gens::string(0..12), gens::bytes(0..256)),
        0..20,
    );
    forall(gen, 200, |items| {
        let value: Vec<(String, Bytes)> = items
            .iter()
            .map(|(s, b)| (s.clone(), Bytes(b.clone())))
            .collect();
        let wire = value.to_bytes();
        Vec::<(String, Bytes)>::from_bytes(&wire).map(|back| back == value)
            .unwrap_or(false)
    });
}

#[test]
fn prop_codec_f32s_roundtrip() {
    forall(gens::vec(gens::u64(0..1_000_000), 0..64), 100, |xs| {
        let floats: Vec<f32> = xs.iter().map(|&x| x as f32 * 0.5 - 7.0).collect();
        let v = F32s(floats.clone());
        F32s::from_bytes(&v.to_bytes()).map(|b| b.0 == floats).unwrap_or(false)
    });
}

#[test]
fn prop_codec_rejects_truncation() {
    forall(gens::bytes(1..128), 100, |data| {
        let wire = Bytes(data.clone()).to_bytes();
        // Any strict prefix must fail to decode fully.
        (0..wire.len()).all(|cut| Bytes::from_bytes(&wire[..cut]).is_err())
    });
}

// ---------------------------------------------------------------------
// KV engine vs a model HashMap under random op sequences.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
enum KvOp {
    Set(String, Vec<u8>),
    Del(String),
    Get(String),
    Incr(String, i64),
}

struct KvOpGen;

impl Gen for KvOpGen {
    type Value = KvOp;

    fn generate(&self, rng: &mut proxystore::rng::Rng) -> KvOp {
        let key = format!("k{}", rng.gen_range(5));
        match rng.gen_range(4) {
            0 => {
                let n = rng.usize_in(0, 32);
                KvOp::Set(key, rng.bytes(n))
            }
            1 => KvOp::Del(key),
            2 => KvOp::Get(key),
            _ => KvOp::Incr(key, rng.gen_range(10) as i64 - 5),
        }
    }
}

#[test]
fn prop_kv_matches_model_hashmap() {
    forall(gens::vec(KvOpGen, 1..60), 150, |ops| {
        let kv = KvState::new();
        let mut model: HashMap<String, Vec<u8>> = HashMap::new();
        let mut counters: HashMap<String, i64> = HashMap::new();
        for op in ops {
            match op {
                KvOp::Set(k, v) => {
                    kv.set(k, Bytes(v.clone()));
                    model.insert(k.clone(), v.clone());
                }
                KvOp::Del(k) => {
                    let was = kv.del(k);
                    let want = model.remove(k).is_some();
                    if was != want {
                        return false;
                    }
                }
                KvOp::Get(k) => {
                    let got = kv.get(k).map(|b| b.0);
                    if got != model.get(k).cloned() {
                        return false;
                    }
                }
                KvOp::Incr(k, by) => {
                    let got = kv.incr(k, *by);
                    let c = counters.entry(k.clone()).or_insert(0);
                    *c += by;
                    if got != *c {
                        return false;
                    }
                }
            }
        }
        // Gauge equals total resident bytes.
        let resident: usize = model.values().map(|v| v.len()).sum();
        kv.gauge.get() == resident as i64
    });
}

// ---------------------------------------------------------------------
// Broker: per-topic order preserved, offsets dense, no loss.
// ---------------------------------------------------------------------

#[test]
fn prop_broker_order_and_completeness() {
    forall(
        gens::pair(gens::usize(1..4), gens::vec(gens::bytes(0..64), 1..40)),
        60,
        |(topics, payloads)| {
            let broker = BrokerState::new();
            let mut per_topic: Vec<Vec<Vec<u8>>> = vec![Vec::new(); *topics];
            for (i, p) in payloads.iter().enumerate() {
                let t = i % topics;
                let off = broker.produce(&format!("t{t}"), Bytes(p.clone()));
                if off != per_topic[t].len() as u64 {
                    return false; // offsets must be dense per topic
                }
                per_topic[t].push(p.clone());
            }
            // Replay each topic from 0 and compare order + content.
            (0..*topics).all(|t| {
                let got = broker.fetch(
                    &format!("t{t}"),
                    0,
                    u32::MAX,
                    std::time::Duration::ZERO,
                );
                got.len() == per_topic[t].len()
                    && got
                        .iter()
                        .zip(&per_topic[t])
                        .all(|(e, want)| &e.payload.0 == want)
            })
        },
    );
}

// ---------------------------------------------------------------------
// Ownership: random borrow/drop orders never corrupt state; the object
// is resident iff an owner or borrow is still live; no violations when
// drops happen in stack order.
// ---------------------------------------------------------------------

#[test]
fn prop_ownership_state_machine() {
    forall(
        gens::vec(gens::u64(0..3), 1..20),
        100,
        |script| {
            take_violations();
            let store = Store::memory("prop-own");
            let owned = store.owned_proxy(&Bytes(vec![1; 64])).unwrap();
            let key = owned.key().to_string();
            let mut reads = Vec::new();
            let mut wrote = false;
            for step in script {
                match step {
                    0 => {
                        // borrow: legal iff no mut outstanding.
                        match owned.borrow() {
                            Ok(r) => reads.push(r),
                            Err(_) => {
                                if !wrote {
                                    return false; // must succeed without mut
                                }
                            }
                        }
                    }
                    1 => {
                        // mut borrow: legal iff nothing outstanding. We
                        // immediately release it (stack discipline).
                        match owned.mut_borrow() {
                            Ok(m) => {
                                wrote = false;
                                drop(m);
                            }
                            Err(_) => {
                                if reads.is_empty() {
                                    return false;
                                }
                            }
                        }
                    }
                    _ => {
                        reads.pop(); // release one reader
                    }
                }
                // Invariant: target resident while the owner lives.
                if !store.exists(&key).unwrap() {
                    return false;
                }
            }
            drop(reads);
            drop(owned);
            // Owner gone, all readers released in-line: evicted, clean.
            store.exists(&key).unwrap() == false && take_violations() == 0
        },
    );
}

// ---------------------------------------------------------------------
// Engine: every submitted task runs exactly once, results map 1:1.
// ---------------------------------------------------------------------

#[test]
fn prop_engine_exactly_once() {
    forall(
        gens::pair(gens::usize(1..6), gens::usize(1..80)),
        30,
        |(workers, tasks)| {
            let cluster = Arc::new(LocalCluster::new(ClusterConfig {
                workers: *workers,
                ..Default::default()
            }));
            let counter = Arc::new(std::sync::atomic::AtomicU64::new(0));
            let futs: Vec<_> = (0..*tasks)
                .map(|i| {
                    let c = counter.clone();
                    cluster.submit(
                        Box::new(move |_, payload| {
                            c.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                            let x = u64::from_bytes(&payload)?;
                            Ok((x * 3).to_bytes())
                        }),
                        (i as u64).to_bytes(),
                    )
                })
                .collect();
            let ok = futs.iter().enumerate().all(|(i, f)| {
                u64::from_bytes(&f.wait().unwrap()).unwrap() == (i as u64) * 3
            });
            ok && counter.load(std::sync::atomic::Ordering::SeqCst)
                == *tasks as u64
        },
    );
}

// ---------------------------------------------------------------------
// Stream plugins: batching preserves the item multiset (via metadata).
// ---------------------------------------------------------------------

#[test]
fn prop_stream_batching_preserves_items() {
    forall(
        gens::pair(gens::usize(1..6), gens::usize(1..40)),
        40,
        |(k, items)| {
            let broker = BrokerState::new();
            let store = Store::memory("prop-batch");
            let mut producer = StreamProducer::new(
                EmbeddedLogPublisher::new(broker.clone()),
                Some(store),
            );
            producer.add_plugin(Box::new(BatchAggregator::new(*k)));
            for i in 0..*items {
                let mut md = Metadata::new();
                md.insert(format!("item-{i}"), "1".into());
                producer.send("t", &(i as u64), md).unwrap();
            }
            producer.close_topic("t").unwrap();

            let mut consumer = StreamConsumer::new(
                EmbeddedLogSubscriber::new(broker, "t"),
            );
            let mut seen = std::collections::BTreeSet::new();
            let mut batches = 0usize;
            while let Some(ev) = consumer
                .next_event(Some(std::time::Duration::from_secs(2)))
                .unwrap()
            {
                batches += 1;
                for key in ev.metadata.keys() {
                    if let Some(idx) = key.strip_prefix("item-") {
                        seen.insert(idx.parse::<usize>().unwrap());
                    }
                }
            }
            // Full batches arrive; a trailing partial batch (< k items) is
            // held back by the aggregator — exactly floor(items/k) events.
            batches == items / k
                && seen.len() == (items / k) * k
                && seen.iter().all(|&i| i < *items)
        },
    );
}

// ---------------------------------------------------------------------
// Sampling plugin at rate p keeps ~p of events (statistical bound).
// ---------------------------------------------------------------------

#[test]
fn prop_sampling_rate_statistics() {
    forall(gens::u64(1..10), 9, |&tenths| {
        let rate = tenths as f64 / 10.0;
        let mut plugin = proxystore::stream::SamplePlugin::new(rate, 99);
        let n = 2000;
        let kept = (0..n)
            .filter(|&i| {
                plugin
                    .process(proxystore::stream::Event {
                        topic: "t".into(),
                        seq: i,
                        factory: None,
                        inline: None,
                        metadata: Metadata::new(),
                        end_of_stream: false,
                    })
                    .is_some()
            })
            .count();
        let expected = rate * n as f64;
        (kept as f64 - expected).abs() < 5.0 * (n as f64 * rate * (1.0 - rate)).sqrt().max(10.0)
    });
}
