//! §Perf probes: the L3 hot-path numbers recorded in EXPERIMENTS.md.
//!
//! * engine dispatch rate (no-op tasks through the scheduler queue);
//! * bulk codec throughput: `Bytes` (memcpy) vs element-wise `Vec<u8>`;
//! * proxy put+resolve overhead vs wire time at 10 MB;
//! * stream event handling rate (dispatcher side, tiny events).

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::benchlib::{fmt_secs, once, Bench, Scale};
use proxystore::broker::BrokerState;
use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::engine::{ClusterConfig, LocalCluster};
use proxystore::prelude::{Proxy, Store};
use proxystore::store::ThrottledConnector;
use proxystore::stream::{
    EmbeddedLogPublisher, EmbeddedLogSubscriber, Metadata, StreamConsumer,
    StreamProducer,
};

fn main() {
    let scale = Scale::from_env();
    let mut bench = Bench::new("perf_probe", "probe,metric,value");

    // ------------------------------------------------------------------
    // Engine dispatch rate.
    // ------------------------------------------------------------------
    let n_tasks = scale.pick(5_000usize, 50_000, 200_000);
    let cluster = Arc::new(LocalCluster::new(ClusterConfig {
        workers: 1,
        ..Default::default()
    }));
    let (last, dt) = once(|| {
        let mut last = None;
        for _ in 0..n_tasks {
            last = Some(cluster.submit(Box::new(|_, _| Ok(Vec::new())), vec![]));
        }
        last.unwrap().wait().unwrap();
        while cluster.completed() < n_tasks as u64 {
            std::thread::sleep(Duration::from_millis(1));
        }
    });
    let _ = last;
    let rate = n_tasks as f64 / dt;
    bench.row(format!("engine-dispatch,tasks_per_sec,{rate:.0}"));
    println!("  engine dispatch: {rate:.0} tasks/s over {n_tasks} tasks");

    // ------------------------------------------------------------------
    // Codec: Bytes (memcpy) vs element-wise Vec<u8> for 10 MB.
    // ------------------------------------------------------------------
    let payload = vec![7u8; 10_000_000];
    let reps = scale.pick(3, 10, 30);
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(Bytes(payload.clone()).to_bytes());
    }
    let bulk = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(payload.to_bytes()); // Vec<u8>: element-wise
    }
    let naive = t0.elapsed().as_secs_f64() / reps as f64;
    bench.row(format!("codec-10MB,bytes_memcpy_s,{bulk:.6}"));
    bench.row(format!("codec-10MB,vec_elementwise_s,{naive:.6}"));
    println!(
        "  codec 10MB encode: Bytes {} vs element-wise Vec<u8> {} ({:.1}x)",
        fmt_secs(bulk),
        fmt_secs(naive),
        naive / bulk
    );

    // ------------------------------------------------------------------
    // Proxy overhead vs wire time at 10 MB on a modelled 1 GB/s store.
    // ------------------------------------------------------------------
    let throttled = Store::new(
        "probe-throttled",
        ThrottledConnector::wrap(
            proxystore::store::MemoryConnector::new(),
            Duration::ZERO,
            1.0e9,
        ),
    );
    let raw = Store::memory("probe-raw");
    let data = Bytes(payload);
    let measure = |store: &Store| {
        let t0 = Instant::now();
        let p: Proxy<Bytes> = store.proxy(&data).unwrap();
        let fresh: Proxy<Bytes> =
            Proxy::from_factory(p.factory().clone());
        std::hint::black_box(fresh.into_inner().unwrap().0.len());
        store.evict(p.key()).unwrap();
        t0.elapsed().as_secs_f64()
    };
    // warmup + best-of
    let total: f64 = (0..5).map(|_| measure(&throttled)).fold(f64::MAX, f64::min);
    let overhead: f64 = (0..5).map(|_| measure(&raw)).fold(f64::MAX, f64::min);
    let wire = 2.0 * 10_000_000.0 / 1.0e9;
    bench.row(format!("proxy-10MB,total_s,{total:.6}"));
    bench.row(format!("proxy-10MB,overhead_s,{overhead:.6}"));
    bench.row(format!("proxy-10MB,wire_s,{wire:.6}"));
    println!(
        "  proxy 10MB put+resolve: total {} (wire {}), overhead {} = {:.1}% of wire",
        fmt_secs(total),
        fmt_secs(wire),
        fmt_secs(overhead),
        100.0 * overhead / wire
    );

    // ------------------------------------------------------------------
    // Stream event handling rate (dispatcher side, marker events).
    // ------------------------------------------------------------------
    let broker = BrokerState::new();
    let n_events = scale.pick(2_000usize, 20_000, 50_000);
    let mut producer: StreamProducer<EmbeddedLogPublisher> =
        StreamProducer::new(EmbeddedLogPublisher::new(broker.clone()), None);
    for i in 0..n_events {
        let mut md = Metadata::new();
        md.insert("step".into(), i.to_string());
        producer.send_marker("t", md).unwrap();
    }
    producer.close_topic("t").unwrap();
    let mut consumer =
        StreamConsumer::new(EmbeddedLogSubscriber::new(broker, "t"));
    let (count, dt) = once(|| {
        let mut count = 0usize;
        while let Some(_ev) =
            consumer.next_event(Some(Duration::from_secs(5))).unwrap()
        {
            count += 1;
        }
        count
    });
    assert_eq!(count, n_events);
    let ev_rate = count as f64 / dt;
    bench.row(format!("stream-events,events_per_sec,{ev_rate:.0}"));
    println!("  stream dispatcher: {ev_rate:.0} events/s");

    bench.finish();
}
