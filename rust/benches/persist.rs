//! Durable vs RAM-only write-path overhead over the TCP KV wire: the
//! acceptance bench for the durability plane.
//!
//! Three servers, identical ingress, one pipelined connection each:
//! RAM-only (no durability), WAL with group commit every 256 records
//! (the default production policy), and WAL with fsync per op (the
//! strongest policy, reported for context). Rounds interleave the modes
//! and the best round per mode is kept, so transient noise hits every
//! mode equally. Acceptance bar: group-commit durable puts sustain
//! >= 70% of RAM-only throughput.

use proxystore::benchlib::{once, results_dir, Bench, Scale};
use proxystore::kv::KvClient;
use proxystore::net::ServerBuilder;
use proxystore::ops::Op;
use proxystore::persist::{DurabilityOptions, FsyncPolicy};

const WINDOW: usize = 64;

/// Root for bench data dirs: tmpfs when available (so the bench measures
/// the WAL write path, not the CI host's disk), else the system temp dir.
fn scratch_root() -> std::path::PathBuf {
    let shm = std::path::Path::new("/dev/shm");
    let base = if shm.is_dir() {
        shm.to_path_buf()
    } else {
        std::env::temp_dir()
    };
    base.join(format!("proxystore-bench-persist-{}", std::process::id()))
}

/// ops/sec for `n_ops` pipelined puts on one connection.
fn pipelined_puts(client: &KvClient, n_ops: usize, payload: &[u8]) -> f64 {
    let (_, secs) = once(|| {
        let mut handles = Vec::with_capacity(WINDOW);
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Put {
                key: format!("k-{i}"),
                data: payload.to_vec(),
            }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    h.wait().expect("put").into_unit().expect("unit");
                }
            }
        }
        for h in handles {
            h.wait().expect("put").into_unit().expect("unit");
        }
    });
    n_ops as f64 / secs
}

struct Mode {
    name: &'static str,
    client: KvClient,
    best: f64,
}

fn main() {
    let scale = Scale::from_env();
    let n_ops = scale.pick(2048, 16384, 65536);
    let rounds = scale.pick(2, 3, 5);
    let payload = vec![7u8; 256];
    let root = scratch_root();

    // All three servers stay up for the whole bench; keys are wiped
    // between rounds so resident size stays flat.
    let ram = ServerBuilder::new().spawn_kv().expect("ram server");
    let group = ServerBuilder::new()
        .durability(
            DurabilityOptions::new(root.join("group"))
                .fsync(FsyncPolicy::EveryN(256)),
        )
        .spawn_kv()
        .expect("group-commit server");
    let every = ServerBuilder::new()
        .durability(
            DurabilityOptions::new(root.join("everyop"))
                .fsync(FsyncPolicy::EveryOp),
        )
        .spawn_kv()
        .expect("fsync-per-op server");

    let mut modes = [
        Mode {
            name: "ram",
            client: KvClient::connect(ram.addr).expect("client"),
            best: 0.0,
        },
        Mode {
            name: "wal_group256",
            client: KvClient::connect(group.addr).expect("client"),
            best: 0.0,
        },
        Mode {
            name: "wal_everyop",
            client: KvClient::connect(every.addr).expect("client"),
            best: 0.0,
        },
    ];

    let mut bench =
        Bench::new("persist", "mode,round,put_ops_s,best_ops_s");
    bench.note(&format!(
        "{n_ops} pipelined 256B puts per round, {rounds} interleaved \
         rounds, window {WINDOW}, data dirs under {}",
        root.display()
    ));

    for mode in modes.iter_mut() {
        // Warm: connection, allocator, and (for durable modes) the WAL's
        // first segment + dir fsyncs.
        pipelined_puts(&mode.client, WINDOW * 4, &payload);
        mode.client.flush_all().expect("flush");
    }

    for round in 0..rounds {
        for mode in modes.iter_mut() {
            let ops_s = pipelined_puts(&mode.client, n_ops, &payload);
            mode.best = mode.best.max(ops_s);
            bench.row(format!(
                "{},{round},{ops_s:.0},{:.0}",
                mode.name, mode.best
            ));
            mode.client.flush_all().expect("flush");
        }
    }

    let ram_best = modes[0].best;
    let group_best = modes[1].best;
    let every_best = modes[2].best;
    let ratio = group_best / ram_best;
    bench.note(&format!(
        "fsync-per-op sustains {:.0}% of RAM-only (no bar; strongest \
         policy, reported for context)",
        100.0 * every_best / ram_best
    ));
    bench.compare(
        "group-commit durable put throughput vs RAM-only",
        ">=70%",
        &format!("{:.0}%", ratio * 100.0),
        ratio >= 0.70,
    );
    bench.finish();
    println!("  (results under {})", results_dir());

    let _ = std::fs::remove_dir_all(&root);
    assert!(
        ratio >= 0.70,
        "durable write path too slow: group-commit puts at \
         {group_best:.0} ops/s vs RAM-only {ram_best:.0} ops/s \
         ({:.0}% < 70%)",
        ratio * 100.0
    );
}
