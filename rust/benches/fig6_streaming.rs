//! Fig 6 (paper §V-B): stream-processing throughput vs item size and
//! worker count for Redis-pub/sub-inline, ADIOS-like step store, and
//! ProxyStream.
//!
//! Expected shape: all three comparable at small d; the inline baseline
//! collapses as d·n grows (dispatcher NIC saturation); ProxyStream ≥
//! ADIOS-like without task-code changes. Paper headline: ProxyStream
//! 4.6×/6.2× over Redis pub/sub at 1 MB/10 MB, 1.7×/2.0× over ADIOS2.

use std::time::Duration;

use proxystore::apps::streambench::{run, StreamBenchConfig, StreamMode};
use proxystore::benchlib::{fmt_bytes, Bench, Scale};

fn main() {
    let scale = Scale::from_env();
    let sizes: Vec<usize> = match scale {
        Scale::Smoke => vec![100_000, 1_000_000],
        Scale::Default => vec![100_000, 1_000_000, 10_000_000],
        Scale::Full => vec![100_000, 1_000_000, 10_000_000, 50_000_000],
    };
    let worker_counts: Vec<usize> = match scale {
        Scale::Smoke => vec![4],
        Scale::Default => vec![4, 8, 16],
        Scale::Full => vec![4, 8, 16, 32],
    };
    let task_ms = scale.pick(100u64, 200, 500);
    let items_per_worker = scale.pick(4usize, 6, 10);

    let mut bench =
        Bench::new("fig6_streaming", "mode,workers,size_bytes,tasks_per_sec");
    bench.note(&format!(
        "task s={task_ms}ms, dispatcher NIC 100MB/s (paper's observed rate)"
    ));

    let mut results = Vec::new();
    for &workers in &worker_counts {
        for &size in &sizes {
            for mode in StreamMode::all() {
                let cfg = StreamBenchConfig {
                    workers,
                    data_size: size,
                    task_time: Duration::from_millis(task_ms),
                    items: (workers - 1) * items_per_worker,
                    dispatcher_bw: 1.0e8,
                    broker_instances: 1,
                    seed: 6,
                };
                let r = run(&cfg, mode).expect("fig6 run");
                bench.row(format!(
                    "{},{workers},{size},{:.2}",
                    mode.label(),
                    r.tasks_per_sec
                ));
                results.push((mode, workers, size, r.tasks_per_sec));
            }
        }
    }

    // Shape checks at the largest configuration.
    let (&max_w, &max_d) =
        (worker_counts.iter().max().unwrap(), sizes.iter().max().unwrap());
    let rate = |m: StreamMode| {
        results
            .iter()
            .find(|(mode, w, d, _)| *mode == m && *w == max_w && *d == max_d)
            .map(|(_, _, _, r)| *r)
            .unwrap_or(0.0)
    };
    let (inline, adios, proxy) = (
        rate(StreamMode::PubSubInline),
        rate(StreamMode::StepStore),
        rate(StreamMode::ProxyStream),
    );
    bench.compare(
        &format!(
            "ProxyStream vs Redis-pub/sub at n={max_w}, d={}",
            fmt_bytes(max_d)
        ),
        "4.6–7.3× faster",
        &format!("{:.1}×", proxy / inline.max(1e-9)),
        proxy > inline * 1.5,
    );
    bench.compare(
        "ProxyStream vs ADIOS-like",
        "≥1× (1.7–2.0× at mid sizes)",
        &format!("{:.2}×", proxy / adios.max(1e-9)),
        proxy >= adios * 0.8,
    );
    // Small-d parity.
    let small = sizes[0];
    let small_rates: Vec<f64> = StreamMode::all()
        .iter()
        .map(|&m| {
            results
                .iter()
                .find(|(mode, w, d, _)| {
                    *mode == m && *w == worker_counts[0] && *d == small
                })
                .map(|(_, _, _, r)| *r)
                .unwrap()
        })
        .collect();
    let spread = small_rates.iter().cloned().fold(f64::MIN, f64::max)
        / small_rates.iter().cloned().fold(f64::MAX, f64::min);
    bench.compare(
        &format!("parity at d={}", fmt_bytes(small)),
        "comparable across methods",
        &format!("max/min = {spread:.2}"),
        spread < 2.0,
    );

    // ------------------------------------------------------------------
    // Partitioned event channel: the same ProxyStream workload over a
    // 1/2/4/8-instance broker fabric. In proxy mode the events are tiny,
    // so throughput should hold steady across topologies — the broker
    // fabric's own scaling story is measured by `broker_fabric` where the
    // event channel IS the bottleneck.
    // ------------------------------------------------------------------
    let workers = worker_counts[0];
    for instances in [1usize, 2, 4, 8] {
        let cfg = StreamBenchConfig {
            workers,
            data_size: sizes[0],
            task_time: Duration::from_millis(task_ms),
            items: (workers - 1) * items_per_worker,
            dispatcher_bw: 1.0e8,
            broker_instances: instances,
            seed: 6,
        };
        let r = run(&cfg, StreamMode::ProxyStream).expect("fig6 fabric run");
        bench.row(format!(
            "proxystream-{instances}brokers,{workers},{},{:.2}",
            sizes[0], r.tasks_per_sec
        ));
    }
    bench.finish();
}
