//! Telemetry overhead gate: the always-on instrumentation must be free
//! enough to leave on.
//!
//! Both modes drive the same pipelined put/get workload over one TCP KV
//! connection — the hottest instrumented path in the crate (client op
//! counters + latency histogram, server frame counters + op histogram,
//! per-op trace gating). "enabled" is the default shipping configuration;
//! "disabled" turns every record into a load-and-skip via
//! [`telemetry::set_enabled`]. Acceptance bar: enabled throughput within
//! 5% of disabled (best-of-N, modes interleaved so drift hits both).

use proxystore::benchlib::{once, Bench, Scale};
use proxystore::kv::KvClient;
use proxystore::net::ServerBuilder;
use proxystore::metrics::telemetry;
use proxystore::ops::Op;

const WINDOW: usize = 64;

/// ops/sec for `n_ops` pipelined puts then `n_ops` pipelined gets.
fn pipelined_roundtrip(client: &KvClient, n_ops: usize, payload: &[u8]) -> f64 {
    let (_, secs) = once(|| {
        let mut handles = Vec::with_capacity(WINDOW);
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Put {
                key: format!("t-{i}"),
                data: payload.to_vec(),
            }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    h.wait().expect("pipelined put");
                }
            }
        }
        for h in handles.drain(..) {
            h.wait().expect("pipelined put");
        }
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Get { key: format!("t-{i}") }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    h.wait().expect("pipelined get");
                }
            }
        }
        for h in handles {
            h.wait().expect("pipelined get");
        }
    });
    (2 * n_ops) as f64 / secs
}

fn main() {
    let scale = Scale::from_env();
    let n_ops = scale.pick(512, 4096, 16384);
    let reps = scale.pick(3, 5, 7);
    let payload = vec![7u8; 256];

    let server = ServerBuilder::new().spawn_kv().expect("kv server");
    let client = KvClient::connect(server.addr).expect("client");

    let mut bench = Bench::new("telemetry", "mode,best_ops_s");
    bench.note(&format!(
        "{n_ops} puts + {n_ops} gets per rep, {reps} reps per mode, \
         window {WINDOW}, 256B payloads, one TCP connection"
    ));

    // Warm connection, allocator, and both telemetry states once.
    telemetry::set_enabled(false);
    pipelined_roundtrip(&client, WINDOW, &payload);
    telemetry::set_enabled(true);
    pipelined_roundtrip(&client, WINDOW, &payload);

    // best-of-N, interleaved: rep k runs disabled then enabled, so slow
    // drift (thermal, CI neighbors) degrades both modes alike.
    let mut best = [0.0f64; 2];
    for _ in 0..reps {
        for (slot, on) in [(0usize, false), (1usize, true)] {
            telemetry::set_enabled(on);
            let ops_s = pipelined_roundtrip(&client, n_ops, &payload);
            best[slot] = best[slot].max(ops_s);
        }
    }
    telemetry::set_enabled(true);

    bench.row(format!("disabled,{:.0}", best[0]));
    bench.row(format!("enabled,{:.0}", best[1]));

    let overhead = (best[0] - best[1]) / best[0];
    bench.compare(
        "instrumented pipelined put/get vs uninstrumented",
        "<=5% overhead",
        &format!("{:.1}% overhead", overhead * 100.0),
        overhead <= 0.05,
    );
    bench.finish();
}
