//! Telemetry overhead gate: the always-on instrumentation must be free
//! enough to leave on, and scraping it must be free enough to monitor.
//!
//! All modes drive the same pipelined put/get workload over one TCP KV
//! connection — the hottest instrumented path in the crate (client op
//! counters + latency histogram, server frame counters + op histogram,
//! per-op trace gating). "enabled" is the default shipping configuration;
//! "disabled" turns every record into a load-and-skip via
//! [`telemetry::set_enabled`]; "scraped" keeps telemetry on while a
//! monitoring thread polls the HTTP admin plane (`GET /metrics`) and the
//! Telemetry wire op at 1 Hz, the way a Prometheus scraper plus a cluster
//! snapshot would. Acceptance bars: enabled within 5% of disabled, and
//! scraping within 5% of enabled (best-of-N, modes interleaved so drift
//! hits all three).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use proxystore::benchlib::{once, Bench, Scale};
use proxystore::kv::KvClient;
use proxystore::net::{http_get, ServerBuilder};
use proxystore::metrics::telemetry;
use proxystore::ops::Op;

const WINDOW: usize = 64;

/// ops/sec for `n_ops` pipelined puts then `n_ops` pipelined gets.
fn pipelined_roundtrip(client: &KvClient, n_ops: usize, payload: &[u8]) -> f64 {
    let (_, secs) = once(|| {
        let mut handles = Vec::with_capacity(WINDOW);
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Put {
                key: format!("t-{i}"),
                data: payload.to_vec(),
            }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    h.wait().expect("pipelined put");
                }
            }
        }
        for h in handles.drain(..) {
            h.wait().expect("pipelined put");
        }
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Get { key: format!("t-{i}") }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    h.wait().expect("pipelined get");
                }
            }
        }
        for h in handles {
            h.wait().expect("pipelined get");
        }
    });
    (2 * n_ops) as f64 / secs
}

/// A monitoring sidecar: scrape `/metrics` over HTTP and the registry
/// over the Telemetry wire op immediately, then at 1 Hz until stopped.
/// Returns the scrape count so the gate can prove scrapes happened
/// while the hot path ran.
fn spawn_scraper(
    admin: std::net::SocketAddr,
    data: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<u64> {
    std::thread::spawn(move || {
        let client = KvClient::connect(data).expect("scrape connection");
        let mut scrapes = 0u64;
        while !stop.load(Ordering::Relaxed) {
            let (status, body) =
                http_get(admin, "/metrics").expect("GET /metrics");
            assert_eq!(status, 200, "scrape failed");
            assert!(!body.is_empty(), "empty exposition under load");
            client.telemetry().expect("Telemetry wire op");
            scrapes += 1;
            for _ in 0..100 {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        scrapes
    })
}

fn main() {
    let scale = Scale::from_env();
    let n_ops = scale.pick(512, 4096, 16384);
    let reps = scale.pick(3, 5, 7);
    let payload = vec![7u8; 256];

    // The admin plane is always on (its own event loop beside the data
    // plane): the unscraped modes measure that merely serving it is
    // free; the scraped mode measures answering it.
    let server = ServerBuilder::new()
        .admin_addr("127.0.0.1:0".parse().unwrap())
        .spawn_kv()
        .expect("kv server");
    let admin = server.admin_addr().expect("admin endpoint");
    let client = KvClient::connect(server.addr).expect("client");

    let mut bench = Bench::new("telemetry", "mode,best_ops_s");
    bench.note(&format!(
        "{n_ops} puts + {n_ops} gets per rep, {reps} reps per mode, \
         window {WINDOW}, 256B payloads, one TCP connection; scraped \
         mode polls GET /metrics + Telemetry op at 1 Hz"
    ));

    // Warm connection, allocator, admin plane, and telemetry states.
    telemetry::set_enabled(false);
    pipelined_roundtrip(&client, WINDOW, &payload);
    telemetry::set_enabled(true);
    pipelined_roundtrip(&client, WINDOW, &payload);
    let (status, _) = http_get(admin, "/metrics").expect("warm scrape");
    assert_eq!(status, 200);

    // best-of-N, interleaved: rep k runs disabled, enabled, then
    // enabled-under-scrape, so slow drift (thermal, CI neighbors)
    // degrades every mode alike.
    let mut best = [0.0f64; 3];
    let mut total_scrapes = 0u64;
    for _ in 0..reps {
        for (slot, on, scraped) in
            [(0usize, false, false), (1, true, false), (2, true, true)]
        {
            telemetry::set_enabled(on);
            let ops_s = if scraped {
                let stop = Arc::new(AtomicBool::new(false));
                let scraper =
                    spawn_scraper(admin, server.addr, stop.clone());
                let ops_s = pipelined_roundtrip(&client, n_ops, &payload);
                stop.store(true, Ordering::Relaxed);
                total_scrapes += scraper.join().expect("scraper");
                ops_s
            } else {
                pipelined_roundtrip(&client, n_ops, &payload)
            };
            best[slot] = best[slot].max(ops_s);
        }
    }
    telemetry::set_enabled(true);

    bench.row(format!("disabled,{:.0}", best[0]));
    bench.row(format!("enabled,{:.0}", best[1]));
    bench.row(format!("scraped,{:.0}", best[2]));
    bench.note(&format!(
        "{total_scrapes} scrapes completed across the scraped reps"
    ));
    assert!(total_scrapes > 0, "scraper never ran during the hot path");

    let overhead = (best[0] - best[1]) / best[0];
    bench.compare(
        "instrumented pipelined put/get vs uninstrumented",
        "<=5% overhead",
        &format!("{:.1}% overhead", overhead * 100.0),
        overhead <= 0.05,
    );
    let scrape_cost = (best[1] - best[2]) / best[1];
    bench.compare(
        "1 Hz admin scrape + Telemetry op vs unscraped hot path",
        "<=5% overhead",
        &format!("{:.1}% overhead", scrape_cost * 100.0),
        scrape_cost <= 0.05,
    );
    bench.finish();
}
