//! Fig 10 (paper §VI): active proxies during the MOF Generation
//! application, default proxy management vs the ownership model.
//!
//! Expected shape: default accumulates proxied objects monotonically (the
//! campaign never frees them); ownership evicts as owners/borrows drop,
//! staying near the thinker's working-set size. The physics surrogate is
//! the real `mof_score_c256` PJRT artifact.

use std::sync::Arc;

use proxystore::apps::mof::{run, MemoryMode, MofConfig};
use proxystore::benchlib::{Bench, Scale};
use proxystore::runtime::{default_artifacts_dir, ModelRegistry};

fn main() {
    let scale = Scale::from_env();
    let reg: Arc<ModelRegistry> =
        ModelRegistry::load(default_artifacts_dir()).expect(
            "artifacts missing — run `make artifacts` before `cargo bench`",
        );
    let cfg = MofConfig {
        rounds: scale.pick(3, 6, 12),
        generators: scale.pick(2, 3, 4),
        top_k: scale.pick(2, 8, 16),
        ..Default::default()
    };

    let mut bench =
        Bench::new("fig10_mof", "mode,t_s,active_proxies,store_bytes");
    bench.note(&format!("{cfg:?}"));

    let mut reports = Vec::new();
    for mode in [MemoryMode::Default, MemoryMode::Ownership] {
        let r = run(&cfg, &reg, mode).expect("fig10 run");
        for row in r.series.csv_rows() {
            bench.row(format!("{},{row}", mode.label()));
        }
        println!(
            "  [{}] best={:.4} peak_active={} final_active={}",
            mode.label(),
            r.best_score,
            r.series.peak_active(),
            r.series.final_active()
        );
        reports.push((mode, r));
    }

    let default = &reports[0].1;
    let owned = &reports[1].1;
    bench.compare(
        "default management accumulates proxies",
        "count grows for the whole run",
        &format!("final = {}", default.series.final_active()),
        default.series.final_active() >= default.series.peak_active() / 2
            && default.series.final_active() > 0,
    );
    bench.compare(
        "ownership evicts as lifetimes end",
        "returns to ~0 at campaign end",
        &format!("final = {}", owned.series.final_active()),
        owned.series.final_active() <= 2,
    );
    bench.compare(
        "identical steering decisions",
        "same best candidate",
        &format!("{:.4} vs {:.4}", default.best_score, owned.best_score),
        (default.best_score - owned.best_score).abs() < 1e-5,
    );
    bench.finish();
}
