//! Fig 8 (paper §VI): 1000 Genomes workflow stage timelines, Globus-
//! Compute-native baseline vs ProxyFutures.
//!
//! Expected shape: ProxyFutures reduces makespan (paper: −36%) by
//! overlapping stages 1–3; stages 4/5 gain less (no intra-stage deps).
//! Outputs are checked against the single-process reference on every run.

use std::time::Duration;

use proxystore::apps::genomes::{run, run_reference, GenomesConfig};
use proxystore::benchlib::{fmt_secs, Bench, Scale};
use proxystore::workflow::DataMode;

fn main() {
    let scale = Scale::from_env();
    let cfg = GenomesConfig {
        individuals: scale.pick(24, 64, 128),
        snps_per_chunk: scale.pick(500, 2000, 5000),
        chunks: scale.pick(4, 8, 16),
        groups: scale.pick(2, 4, 8),
        task_overhead: Duration::from_millis(scale.pick(30, 60, 150)),
        compute_floor: Duration::from_millis(scale.pick(20, 40, 100)),
        seed: 1000,
    };

    let mut bench = Bench::new(
        "fig8_genomes",
        "mode,task,stage,start_s,end_s",
    );
    bench.note(&format!("{cfg:?}"));
    let want = run_reference(&cfg);
    bench.note(&format!(
        "reference: {} overlapping variants",
        want.len()
    ));

    let mut makespans = Vec::new();
    for mode in [DataMode::NoProxy, DataMode::ProxyFuture] {
        let (report, freq) = run(&cfg, mode).expect("fig8 run");
        assert_eq!(freq, want, "pipeline output mismatch in {mode:?}");
        for r in report.timeline.records() {
            bench.row(format!(
                "{},{},{},{:.4},{:.4}",
                mode.label(),
                r.task,
                r.stage,
                r.start,
                r.end
            ));
        }
        println!(
            "  [{}] makespan = {}",
            mode.label(),
            fmt_secs(report.makespan)
        );
        makespans.push((mode, report.makespan));
    }

    let base = makespans[0].1;
    let pf = makespans[1].1;
    let reduction = 100.0 * (1.0 - pf / base);
    bench.compare(
        "ProxyFutures makespan reduction",
        "36%",
        &format!("{reduction:.1}%"),
        reduction > 10.0,
    );
    bench.finish();
}
