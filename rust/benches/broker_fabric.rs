//! Broker fabric benchmark: aggregate produce/fetch throughput at 1/2/4/8
//! broker instances, plus partition unavailability when an instance dies.
//!
//! Each instance sits behind a contended throttled link (fixed latency +
//! bandwidth, concurrent transfers serialize), so the single-instance
//! bottleneck the fabric removes is physically present: with one instance
//! every partition's traffic queues on one link, with N instances the
//! per-partition batches move in parallel. The acceptance bar: >= 2x
//! aggregate fetch throughput at 4 instances vs 1, with per-partition
//! ordering verified on every fetched batch.

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::benchlib::{fmt_bytes, Bench, Scale};
use proxystore::broker::{
    BrokerFabric, BrokerState, PartitionBroker, PartitionedConsumer,
    PartitionedProducer, Partitioner, ThrottledBroker,
};
use proxystore::codec::Bytes;
use proxystore::testing::fail::FlakyBroker;

const LINK_LATENCY: Duration = Duration::from_micros(200);
const LINK_BW: f64 = 2.0e8; // 200 MB/s per instance

fn instance() -> Arc<dyn PartitionBroker> {
    ThrottledBroker::wrap(
        Arc::new(BrokerState::new()) as Arc<dyn PartitionBroker>,
        LINK_LATENCY,
        LINK_BW,
    )
}

/// Payload for event `i`: index header + filler (the index lets the
/// consumer assert per-partition ordering on what it fetched).
fn payload(i: u32, size: usize) -> Bytes {
    let mut v = vec![0u8; size.max(4)];
    v[..4].copy_from_slice(&i.to_le_bytes());
    Bytes(v)
}

fn main() {
    let scale = Scale::from_env();
    let samples = scale.pick(2, 4, 8);
    let events = scale.pick(64u32, 256, 1024);
    let size = scale.pick(16 * 1024, 64 * 1024, 256 * 1024);
    // 32 partitions keep the per-instance partition load balanced enough
    // that 4 instances reliably clear the 2x bar (ring placement over few
    // partitions is lumpy; more partitions smooth it).
    let partitions = 32u32;

    let mut bench =
        Bench::new("broker_fabric", "instances,produce_mb_s,fetch_mb_s");
    bench.note(&format!(
        "{events} events x {} over {partitions} partitions, per-instance \
         link {}us + {} MB/s (contended)",
        fmt_bytes(size),
        LINK_LATENCY.as_micros(),
        LINK_BW / 1e6
    ));

    let mb = (events as usize * size.max(4)) as f64 / 1e6;
    let mut fetch_by_instances: Vec<(usize, f64)> = Vec::new();

    for instances in [1usize, 2, 4, 8] {
        let fabric = BrokerFabric::new(
            (0..instances).map(|_| instance()).collect(),
            partitions,
        )
        .expect("fabric");

        let mut produce_s = Vec::with_capacity(samples);
        let mut fetch_s = Vec::with_capacity(samples);
        // First sample doubles as warmup.
        for sample in 0..=samples {
            let topic = format!("bench-{sample}");
            let batch: Vec<(Option<String>, Bytes)> =
                (0..events).map(|i| (None, payload(i, size))).collect();

            let mut producer = PartitionedProducer::new(
                fabric.clone(),
                Partitioner::RoundRobin,
            );
            let t0 = Instant::now();
            producer.produce_many(&topic, batch).expect("produce_many");
            produce_s.push(t0.elapsed().as_secs_f64());

            let mut consumer =
                PartitionedConsumer::new(fabric.clone(), &topic, 0, 1)
                    .expect("consumer");
            consumer.set_fetch_max(events);
            let mut per_part: Vec<Vec<u32>> =
                vec![Vec::new(); partitions as usize];
            let t0 = Instant::now();
            let mut seen = 0;
            while seen < events {
                let got = consumer
                    .poll(Duration::from_secs(10))
                    .expect("poll");
                assert!(!got.is_empty(), "fetch starved at {seen}/{events}");
                for (p, e) in got {
                    let idx =
                        u32::from_le_bytes(e.payload.0[..4].try_into().unwrap());
                    per_part[p as usize].push(idx);
                    seen += 1;
                }
            }
            fetch_s.push(t0.elapsed().as_secs_f64());
            // Per-partition ordering: round-robin placement means partition
            // p received exactly the ascending run p, p+P, p+2P, ...
            for (p, idxs) in per_part.iter().enumerate() {
                let expect: Vec<u32> = (0..events)
                    .filter(|i| i % partitions == p as u32)
                    .collect();
                assert_eq!(idxs, &expect, "partition {p} misordered");
            }
        }
        produce_s.remove(0);
        fetch_s.remove(0);

        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        let (p_s, f_s) = (mean(&produce_s), mean(&fetch_s));
        fetch_by_instances.push((instances, mb / f_s));
        bench.row(format!("{instances},{:.1},{:.1}", mb / p_s, mb / f_s));
    }

    let tput = |n: usize| {
        fetch_by_instances
            .iter()
            .find(|(i, _)| *i == n)
            .map(|(_, t)| *t)
            .unwrap_or(0.0)
    };
    let speedup = tput(4) / tput(1).max(1e-9);
    bench.compare(
        "fetch throughput, 4 instances vs 1",
        ">= 2x",
        &format!("{speedup:.1}x"),
        speedup >= 2.0,
    );

    // ------------------------------------------------------------------
    // Partition unavailability: the event channel is unreplicated, so a
    // dead instance takes its partitions offline — explicitly, while the
    // surviving partitions keep producing and consuming in order.
    // ------------------------------------------------------------------
    let flaky: Vec<Arc<FlakyBroker>> = (0..4)
        .map(|_| FlakyBroker::wrap(Arc::new(BrokerState::new()) as _))
        .collect();
    let fabric = BrokerFabric::new(
        flaky.iter().map(|f| f.clone() as Arc<dyn PartitionBroker>).collect(),
        partitions,
    )
    .expect("fabric");
    let mut producer =
        PartitionedProducer::new(fabric.clone(), Partitioner::RoundRobin);
    flaky[0].set_down(true);
    let mut dead = 0;
    let mut alive = 0;
    for i in 0..partitions {
        match producer.produce("outage", None, payload(i, 64)) {
            Ok(_) => alive += 1,
            Err(_) => dead += 1,
        }
    }
    flaky[0].set_down(false);
    let mut consumer = PartitionedConsumer::new(fabric, "outage", 0, 1)
        .expect("consumer");
    let survived = {
        let mut n = 0;
        loop {
            let got = consumer.poll(Duration::ZERO).expect("poll");
            if got.is_empty() {
                break n;
            }
            n += got.len();
        }
    };
    assert_eq!(survived, alive, "surviving partitions must retain their log");
    bench.note(&format!(
        "outage: instance 0 of 4 down -> {dead}/{partitions} partitions \
         unavailable, {alive} produced and all {survived} fetched after \
         recovery"
    ));

    bench.finish();
}
