//! C1M-style ingress bench: event-loop vs threaded server ingress.
//!
//! Three measurements, all against real TCP servers in-process:
//!
//! 1. **Throughput at 64 connections** — both ingress modes serve the
//!    same pipelined+coalesced driver fleet; the event loop must match
//!    or beat thread-per-connection on ops/s (gate: >= 0.9x).
//! 2. **Idle-connection sustain** (event only) — ramp thousands of raw
//!    sockets, hold them open, and verify the process thread count
//!    stays bounded while a driver client still gets served. This is
//!    the scenario thread-per-connection cannot survive: 10k parked
//!    connections would mean 10k OS threads.
//! 3. **Wake-to-notify latency** — arm a batch of watches, satisfy
//!    them with `mput`, and report the server-side
//!    `watch.wake_to_notify_us` histogram (armed-watch wake to Notify
//!    frame buffered on the event loop).
//!
//! Scale tiers (`PROXYSTORE_BENCH_SCALE`): smoke sustains 1k idle
//! connections, default 10k, full 20k. The fd limit is raised
//! best-effort via [`raise_nofile_limit`]; the idle target is clamped
//! to what the limit actually allows so a locked-down container
//! degrades gracefully instead of erroring out.

use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use proxystore::benchlib::{once, Bench, Scale};
use proxystore::codec::Bytes;
use proxystore::kv::{
    read_frame, write_frame, ClientOptions, KvClient, Request, Response,
};
use proxystore::net::{raise_nofile_limit, Ingress, ServerBuilder};
use proxystore::ops::Op;

/// Driver threads for the throughput section; 64 connections split
/// evenly across them.
const DRIVERS: usize = 8;
const CONNS: usize = 64;
/// In-flight ops per driver thread before draining completions.
const WINDOW: usize = 64;
/// Threads used to ramp up the idle-connection herd.
const RAMPERS: usize = 8;

fn mode_name(ingress: Ingress) -> &'static str {
    match ingress {
        Ingress::Threaded => "threaded",
        Ingress::EventLoop => "event",
    }
}

/// Total ops/s for `CONNS` pipelined clients driving one server.
fn throughput(ingress: Ingress, ops_per_conn: usize) -> f64 {
    let server = ServerBuilder::new()
        .ingress(ingress)
        .spawn_kv()
        .expect("kv server");
    let addr = server.addr;
    let per_driver = CONNS / DRIVERS;
    let (_, secs) = once(|| {
        let drivers: Vec<_> = (0..DRIVERS)
            .map(|t| {
                std::thread::spawn(move || {
                    let clients: Vec<KvClient> = (0..per_driver)
                        .map(|_| {
                            KvClient::connect_with(
                                addr,
                                ClientOptions::coalescing(),
                            )
                            .expect("driver client")
                        })
                        .collect();
                    let payload = vec![7u8; 64];
                    let mut handles = Vec::with_capacity(WINDOW + CONNS);
                    for i in 0..ops_per_conn {
                        for (c, client) in clients.iter().enumerate() {
                            handles.push(client.submit_op(Op::Put {
                                key: format!("k-{t}-{c}-{}", i % 8),
                                data: payload.clone(),
                            }));
                            if handles.len() >= WINDOW {
                                for h in handles.drain(..) {
                                    h.wait()
                                        .expect("put")
                                        .into_unit()
                                        .expect("unit");
                                }
                            }
                        }
                    }
                    for h in handles {
                        h.wait().expect("put").into_unit().expect("unit");
                    }
                })
            })
            .collect();
        for d in drivers {
            d.join().expect("driver thread");
        }
    });
    (CONNS * ops_per_conn) as f64 / secs
}

/// Open `target` raw connections, prove each is live with one
/// Ping/Pong round trip, and hand the sockets back so the caller can
/// keep them parked. Stops early (gracefully) if the fd limit bites.
fn ramp_idle(addr: SocketAddr, target: usize) -> Vec<TcpStream> {
    let chunk = target / RAMPERS;
    let ramps: Vec<_> = (0..RAMPERS)
        .map(|r| {
            let want = if r == 0 { target - chunk * (RAMPERS - 1) } else { chunk };
            std::thread::spawn(move || {
                let mut streams = Vec::with_capacity(want);
                for _ in 0..want {
                    let mut s = match TcpStream::connect(addr) {
                        Ok(s) => s,
                        Err(_) => break,
                    };
                    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                    if write_frame(&mut s, &Request::Ping).is_err() {
                        break;
                    }
                    match read_frame::<_, Response>(&mut s) {
                        Ok(Some(_)) => streams.push(s),
                        _ => break,
                    }
                }
                streams
            })
        })
        .collect();
    let mut idle = Vec::with_capacity(target);
    for r in ramps {
        idle.extend(r.join().expect("ramp thread"));
    }
    idle
}

/// `Threads:` line from /proc/self/status (0 where unavailable).
fn process_threads() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

fn main() {
    let scale = Scale::from_env();
    let ops_per_conn = scale.pick(64, 256, 1024);
    let mut idle_target = scale.pick(1_000, 10_000, 20_000);
    let n_watch = scale.pick(256, 1024, 4096);

    let mut bench = Bench::new("c1m", "section,metric,value");

    if !cfg!(target_os = "linux") {
        bench.note("event ingress requires Linux; bench skipped");
        bench.finish();
        return;
    }

    match raise_nofile_limit(65_536) {
        Ok(limit) => {
            bench.note(&format!("fd limit: {limit}"));
            // Idle sockets + server-side fds + driver clients all draw
            // from the same budget; leave headroom for everything else.
            let room = (limit as usize / 2).saturating_sub(256);
            if room < idle_target {
                bench.note(&format!(
                    "fd limit clamps idle target {idle_target} -> {room}"
                ));
                idle_target = room.max(64);
            }
        }
        Err(e) => bench.note(&format!("raise_nofile_limit failed: {e}")),
    }

    // ---- 1. Throughput at 64 connections, both ingress modes --------
    let mut ops = [0.0f64; 2];
    for (slot, ingress) in
        [Ingress::Threaded, Ingress::EventLoop].into_iter().enumerate()
    {
        throughput(ingress, 8); // warm: first-touch, thread spawn, paging
        let o = throughput(ingress, ops_per_conn);
        bench.row(format!(
            "throughput_{CONNS}conns,{}_ops_s,{o:.0}",
            mode_name(ingress)
        ));
        ops[slot] = o;
    }
    let ratio = ops[1] / ops[0];
    bench.row(format!("throughput_{CONNS}conns,event_over_threaded,{ratio:.2}"));
    bench.compare(
        &format!("event ingress ops/s at {CONNS} conns vs threaded"),
        ">=0.9x",
        &format!("{ratio:.2}x"),
        ratio >= 0.9,
    );

    // ---- 2. Idle-connection sustain on the event loop ---------------
    {
        let server = ServerBuilder::new()
            .ingress(Ingress::EventLoop)
            .spawn_kv()
            .expect("kv server");
        let (idle, ramp_secs) = once(|| ramp_idle(server.addr, idle_target));
        let achieved = idle.len();
        let threads = process_threads();
        bench.row(format!("sustain,idle_conns_target,{idle_target}"));
        bench.row(format!("sustain,idle_conns_achieved,{achieved}"));
        bench.row(format!(
            "sustain,ramp_conns_per_s,{:.0}",
            achieved as f64 / ramp_secs
        ));
        bench.row(format!("sustain,process_threads,{threads}"));

        // The server must still serve live traffic with the herd parked.
        let driver = KvClient::connect(server.addr).expect("driver");
        let (_, secs) = once(|| {
            let mut handles = Vec::with_capacity(WINDOW);
            for i in 0..2048usize {
                handles.push(driver.submit_op(Op::Put {
                    key: format!("live-{}", i % 8),
                    data: vec![9u8; 64],
                }));
                if handles.len() == WINDOW {
                    for h in handles.drain(..) {
                        h.wait().expect("put").into_unit().expect("unit");
                    }
                }
            }
            for h in handles {
                h.wait().expect("put").into_unit().expect("unit");
            }
        });
        bench.row(format!(
            "sustain,driver_ops_s_under_idle_load,{:.0}",
            2048.0 / secs
        ));

        bench.compare(
            &format!("idle connections sustained (target {idle_target})"),
            &format!(">={idle_target}"),
            &achieved.to_string(),
            achieved >= idle_target,
        );
        bench.compare(
            &format!("process threads bounded with {achieved} idle conns"),
            "<=64",
            &threads.to_string(),
            threads > 0 && threads <= 64,
        );
        drop(idle);
    }

    // ---- 3. Wake-to-notify latency over the event loop --------------
    {
        let server = ServerBuilder::new()
            .ingress(Ingress::EventLoop)
            .spawn_kv()
            .expect("kv server");
        let watcher = KvClient::connect(server.addr).expect("watcher");
        let setter = KvClient::connect(server.addr).expect("setter");

        let before = proxystore::metrics::telemetry::snapshot()
            .histogram("watch.wake_to_notify_us")
            .map(|h| h.count)
            .unwrap_or(0);

        let handles: Vec<_> =
            (0..n_watch).map(|i| watcher.watch(&format!("w-{i}"))).collect();
        // Pipelined FIFO: a ping response proves every Watch before it
        // was armed server-side.
        watcher.ping().expect("arm barrier");

        let mut start = 0usize;
        while start < n_watch {
            let end = (start + 256).min(n_watch);
            let items: Vec<(String, Bytes)> = (start..end)
                .map(|i| (format!("w-{i}"), Bytes(vec![1u8; 32])))
                .collect();
            setter.mput(items).expect("mput");
            start = end;
        }
        for h in handles {
            h.wait().expect("notify");
        }

        let snap = proxystore::metrics::telemetry::snapshot();
        let wake = snap.histogram("watch.wake_to_notify_us");
        let fired = wake.map(|h| h.count - before).unwrap_or(0);
        let (p50, p99) = wake
            .map(|h| (h.percentile(50.0), h.percentile(99.0)))
            .unwrap_or((0.0, 0.0));
        bench.row(format!("wake,notifies,{fired}"));
        bench.row(format!("wake,p50_us,{p50:.1}"));
        bench.row(format!("wake,p99_us,{p99:.1}"));
        bench.compare(
            &format!("every armed watch notified ({n_watch} watches)"),
            &format!(">={n_watch}"),
            &fired.to_string(),
            fired >= n_watch as u64,
        );
    }

    bench.finish();
}
