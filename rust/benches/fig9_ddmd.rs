//! Fig 9 (paper §VI): DeepDriveMD inference round-trip time, baseline
//! (task-per-batch) vs ProxyStream (persistent inference actor).
//!
//! Inference is the real PJRT execution of the JAX+Pallas autoencoder
//! (`encode_b{1,8,32}` artifacts). Expected shape: ProxyStream cuts mean
//! RTT (paper: 21.9 s → 15.0 s, −32%) and processes more batches in the
//! same wall time (+21%); RTT grows with batch size in both.

use std::sync::Arc;

use proxystore::apps::ddmd::{
    run_baseline, run_proxystream, DdmdConfig,
};
use proxystore::benchlib::{fmt_secs, Bench, Scale};
use proxystore::runtime::{default_artifacts_dir, ModelRegistry};

fn main() {
    let scale = Scale::from_env();
    let reg: Arc<ModelRegistry> =
        ModelRegistry::load(default_artifacts_dir()).expect(
            "artifacts missing — run `make artifacts` before `cargo bench`",
        );
    let cfg = DdmdConfig {
        rounds: scale.pick(5, 12, 30),
        initial_batch: 2,
        batch_growth: scale.pick(3, 2, 1),
        train: !matches!(scale, Scale::Smoke),
        ..Default::default()
    };

    let mut bench = Bench::new("fig9_ddmd", "mode,round,batch,rtt_s");
    bench.note(&format!("{cfg:?}"));

    let base = run_baseline(&cfg, &reg).expect("baseline run");
    for r in &base.rounds {
        bench.row(format!("baseline,{},{},{:.4}", r.round, r.batch, r.rtt));
    }
    let ps = run_proxystream(&cfg, &reg).expect("proxystream run");
    for r in &ps.rounds {
        bench.row(format!("proxystream,{},{},{:.4}", r.round, r.batch, r.rtt));
    }

    println!(
        "  baseline mean RTT    = {}",
        fmt_secs(base.mean_rtt)
    );
    println!(
        "  proxystream mean RTT = {} ({} model updates applied)",
        fmt_secs(ps.mean_rtt),
        ps.model_updates
    );

    let reduction = 100.0 * (1.0 - ps.mean_rtt / base.mean_rtt);
    bench.compare(
        "inference RTT reduction",
        "32% (21.9s → 15.0s)",
        &format!("{reduction:.1}%"),
        reduction > 10.0,
    );
    let throughput_gain = base.mean_rtt / ps.mean_rtt;
    bench.compare(
        "batches per wall-clock",
        "+21%",
        &format!("+{:.0}%", (throughput_gain - 1.0) * 100.0),
        throughput_gain > 1.05,
    );
    // Numerics agree when training is off; with training the actor's model
    // advances, so only the baseline-vs-baseline determinism is asserted.
    if !cfg.train {
        assert!(
            (base.checksum - ps.checksum).abs()
                < 1e-3 * base.checksum.abs().max(1.0),
            "latent checksums diverged"
        );
        bench.note("checksums agree across modes");
    }
    bench.finish();
}
