//! Fig 5 (paper §V-A): task pipelining with ProxyFutures.
//!
//! Regenerates both panels: (a) task-lifecycle Gantt charts for no-proxy /
//! proxy / ProxyFuture at f=0.2 and f=0.5; (b) makespan vs overhead
//! fraction f for the three deployments plus the theoretical pipeline
//! limit. Expected shape (paper): Proxy under No-Proxy (~12%); ProxyFuture
//! tracks the theoretical limit (−19.6% at f=0.2), diverging slightly at
//! high f.

use std::time::Duration;

use proxystore::benchlib::{Bench, Scale};
use proxystore::engine::ClusterConfig;
use proxystore::prelude::Store;
use proxystore::workflow::{cluster_for, synthetic_chain, DataMode};

fn main() {
    let scale = Scale::from_env();
    let n = 8;
    let task_ms = scale.pick(100u64, 300, 1000);
    let d = scale.pick(1_000_000usize, 10_000_000, 10_000_000);
    let s = Duration::from_millis(task_ms);
    let fs: Vec<f64> = match scale {
        Scale::Smoke => vec![0.2, 0.5],
        _ => (0..=9).map(|i| i as f64 / 10.0).collect(),
    };

    let mut bench = Bench::new("fig5_pipelining", "f,mode,makespan_s,ideal_s");
    bench.note(&format!("n={n} tasks, s={task_ms}ms, d={d}B"));

    let run = |mode: DataMode, f: f64| {
        let chain = synthetic_chain(n, s, f, d);
        let cluster = cluster_for(
            n,
            ClusterConfig {
                submit_overhead: Duration::from_millis(5),
                ..Default::default()
            },
        );
        let store = Store::memory("fig5");
        chain.run(&cluster, &store, mode).expect("fig5 run")
    };

    // Panel (a): Gantt charts at f=0.2 (all modes) and f=0.5 (ProxyFuture).
    for (mode, f) in [
        (DataMode::NoProxy, 0.2),
        (DataMode::Proxy, 0.2),
        (DataMode::ProxyFuture, 0.2),
        (DataMode::ProxyFuture, 0.5),
    ] {
        let report = run(mode, f);
        println!("\n--- schedule: {} f={f} ---", mode.label());
        print!("{}", report.timeline.ascii_gantt(64));
    }

    // Panel (b): makespan vs f.
    let mut no_proxy_at = Vec::new();
    let mut pf_at = Vec::new();
    for &f in &fs {
        // Ideal pipelined makespan: s + (n-1)(1-f)s.
        let ideal = s.as_secs_f64() * (1.0 + (n - 1) as f64 * (1.0 - f));
        for mode in [DataMode::NoProxy, DataMode::Proxy, DataMode::ProxyFuture]
        {
            let report = run(mode, f);
            bench.row(format!(
                "{f:.1},{},{:.4},{ideal:.4}",
                mode.label(),
                report.makespan
            ));
            if mode == DataMode::NoProxy {
                no_proxy_at.push(report.makespan);
            }
            if mode == DataMode::ProxyFuture {
                pf_at.push((f, report.makespan, ideal));
            }
        }
    }

    // Shape checks vs the paper.
    if let Some((f, got, ideal)) =
        pf_at.iter().find(|(f, _, _)| (*f - 0.2).abs() < 1e-9)
    {
        let base =
            no_proxy_at[fs.iter().position(|x| (x - f).abs() < 1e-9).unwrap()];
        let reduction = 100.0 * (1.0 - got / base);
        bench.compare(
            "ProxyFuture makespan reduction at f=0.2",
            "≈19.6% (ideal 20%)",
            &format!("{reduction:.1}%"),
            (10.0..35.0).contains(&reduction),
        );
        bench.compare(
            "ProxyFuture vs theoretical limit at f=0.2",
            "close to limit",
            &format!("{got:.3}s vs {ideal:.3}s"),
            *got < ideal * 1.25,
        );
    }
    bench.finish();
}
