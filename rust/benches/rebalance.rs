//! Elastic rebalancing benchmark: scale-out and scale-in migration cost
//! at 2/4/8-shard fabrics, with a concurrent reader proving read
//! availability through every membership change.
//!
//! Each backend sits behind a throttled link (fixed latency + bandwidth),
//! so the migration daemon pays real wire time for its batched moves. The
//! acceptance bar: growing N -> N+1 moves ~1/(N+1) of the keys — the
//! consistent-hash locality the control plane exists to exploit — and the
//! reader observes zero misses across all migrations.

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::benchlib::{fmt_bytes, Bench, Scale};
use proxystore::codec::Bytes;
use proxystore::prelude::Store;
use proxystore::shard::{ElasticShards, ShardMembers};
use proxystore::store::{Connector, MemoryConnector, ThrottledConnector};
use proxystore::testing::load::ReadProbe;

const LINK_LATENCY: Duration = Duration::from_micros(200);
const LINK_BW: f64 = 2.0e8; // 200 MB/s per backend

fn backend() -> Arc<dyn Connector> {
    ThrottledConnector::wrap(MemoryConnector::new(), LINK_LATENCY, LINK_BW)
}

fn main() {
    let scale = Scale::from_env();
    let n_keys = scale.pick(48, 192, 768);
    let size = scale.pick(16 * 1024, 64 * 1024, 256 * 1024);

    let mut bench = Bench::new(
        "rebalance",
        "event,shards_before,shards_after,keys,migrated,frac_moved,\
         migrate_s,mb_moved",
    );
    bench.note(&format!(
        "{n_keys} keys x {}, per-backend link {}us + {} MB/s, \
         concurrent reader during every migration",
        fmt_bytes(size),
        LINK_LATENCY.as_micros(),
        LINK_BW / 1e6
    ));

    let mut grow_frac_at_4 = 0.0;
    let mut total_reads = 0u64;
    let mut total_misses = 0u64;

    for shards in [2usize, 4, 8] {
        let members: ShardMembers =
            (0..shards).map(|id| (id, backend())).collect();
        let elastic = ElasticShards::new(
            &format!("bench-rebalance-{shards}"),
            members,
            1,
            0,
        )
        .expect("elastic fabric");
        let store = Store::new("bench", Arc::new(elastic.clone()));
        let objs: Vec<Bytes> =
            (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();
        let keys = store.put_many(&objs).expect("put_many");

        // Scale-out: N -> N+1 under a live reader.
        let probe = ReadProbe::spawn(&store, &keys, 1);
        let before = elastic.metrics();
        let t0 = Instant::now();
        elastic.add_shard(shards, backend()).expect("add_shard");
        assert!(
            elastic.wait_quiescent(Some(Duration::from_secs(300))),
            "grow migration never drained"
        );
        let dt = t0.elapsed().as_secs_f64();
        let m = elastic.metrics();
        let moved = m.keys_migrated - before.keys_migrated;
        let frac = moved as f64 / n_keys as f64;
        let mb = (m.bytes_moved - before.bytes_moved) as f64 / 1e6;
        if shards == 4 {
            grow_frac_at_4 = frac;
        }
        bench.row(format!(
            "grow,{shards},{},{n_keys},{moved},{frac:.3},{dt:.3},{mb:.1}",
            shards + 1
        ));

        // Scale-in: retire the original shard 0, back to N shards.
        let before = elastic.metrics();
        let t0 = Instant::now();
        elastic.remove_shard(0).expect("remove_shard");
        assert!(
            elastic.wait_quiescent(Some(Duration::from_secs(300))),
            "shrink migration never drained"
        );
        let dt = t0.elapsed().as_secs_f64();
        let m = elastic.metrics();
        let moved = m.keys_migrated - before.keys_migrated;
        let frac = moved as f64 / n_keys as f64;
        let mb = (m.bytes_moved - before.bytes_moved) as f64 / 1e6;
        bench.row(format!(
            "shrink,{},{shards},{n_keys},{moved},{frac:.3},{dt:.3},{mb:.1}",
            shards + 1
        ));

        let (reads, misses) = probe.finish();
        total_reads += reads;
        total_misses += misses;

        // Nothing lost: the whole key set resolves on the final fabric.
        let got: Vec<Option<Bytes>> =
            store.get_many(&keys).expect("get_many after rebalances");
        assert!(
            got.iter().all(|b| b.is_some()),
            "keys lost across grow+shrink at {shards} shards"
        );
    }

    bench.compare(
        "scale-out 4->5 moved fraction",
        "~1/5 of keys",
        &format!("{grow_frac_at_4:.2}"),
        grow_frac_at_4 > 0.02 && grow_frac_at_4 < 0.45,
    );
    bench.compare(
        "reader misses during migrations",
        "0",
        &format!("{total_misses} (of {total_reads} reads)"),
        total_misses == 0,
    );
    bench.finish();
}
