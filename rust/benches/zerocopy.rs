//! Zero-copy data plane acceptance bench: large-object GET throughput
//! and bytes-copied-per-op, zero-copy vs copy-mode server egress.
//!
//! Two servers serve the same workload over the same client path
//! ([`KvClient::get_view`], which decodes replies into [`Buf`] windows
//! without copying); the only variable is the reply framing mode.
//! Zero-copy ([`ServerBuilder::zero_copy`]`(true)`, the default) pushes
//! the stored value as a shared segment through the scatter-gather
//! writev path; copy mode re-encodes every reply into one flat buffer —
//! the pre-zero-copy behaviour — and charges the payload to the
//! `data.bytes_copied` counter.
//!
//! Acceptance bars (ISSUE 10): for values >= 1 MiB, zero-copy GET
//! throughput >= 1.5x the copy-mode baseline, and bytes copied per GET
//! in zero-copy mode is O(header) — asserted against the counter, not
//! timed, so it holds at every scale.
//!
//! [`Buf`]: proxystore::codec::Buf

use proxystore::benchlib::{fmt_bytes, once, peak_rss_bytes, Bench, Scale};
use proxystore::codec::Bytes;
use proxystore::kv::KvClient;
use proxystore::metrics::telemetry;
use proxystore::net::ServerBuilder;

/// Bytes/sec reading `key` back `n` times through the zero-copy client
/// surface. Every reply is length-checked so a short read can't fake a
/// fast run.
fn get_view_bytes_per_sec(
    client: &KvClient,
    key: &str,
    n: usize,
    expect_len: usize,
) -> f64 {
    let (_, secs) = once(|| {
        for _ in 0..n {
            let view = client
                .get_view(key)
                .expect("get_view")
                .expect("value present");
            assert_eq!(view.len(), expect_len);
        }
    });
    (n * expect_len) as f64 / secs
}

/// One (mode, size) measurement: throughput plus the exact
/// `data.bytes_copied` delta attributed to the GET loop.
fn run_mode(
    zero_copy: bool,
    size: usize,
    n: usize,
) -> (f64, u64) {
    let server = ServerBuilder::new()
        .zero_copy(zero_copy)
        .spawn_kv()
        .expect("kv server");
    let client = KvClient::connect(server.addr).expect("client");
    client
        .set("blob", Bytes(vec![0xa5; size]))
        .expect("seed value");

    // Warm the path (first-touch page faults, connection ramp) before
    // snapshotting the counter, so the delta covers exactly `n` GETs.
    get_view_bytes_per_sec(&client, "blob", 2, size);
    let copied_before = telemetry::data_metrics().bytes_copied.get();
    let bps = get_view_bytes_per_sec(&client, "blob", n, size);
    let copied = telemetry::data_metrics().bytes_copied.get() - copied_before;
    (bps, copied)
}

fn main() {
    let scale = Scale::from_env();
    // Total bytes moved per (mode, size) run; repetitions shrink as the
    // value grows so the wall clock stays flat across the sweep.
    let budget: usize = scale.pick(8 << 20, 64 << 20, 512 << 20);
    let sizes: &[usize] = &[1 << 20, 8 << 20, 64 << 20];

    let mut bench = Bench::new(
        "zerocopy",
        "mode,payload_bytes,gets,gbytes_s,bytes_copied_per_get",
    );
    bench.note(&format!(
        "~{} per run, get_view client path, loopback TCP",
        fmt_bytes(budget)
    ));

    let mut worst_ratio = f64::INFINITY;
    for &size in sizes {
        if size > budget {
            bench.note(&format!(
                "skipping {} (over {} scale budget)",
                fmt_bytes(size),
                fmt_bytes(budget)
            ));
            continue;
        }
        let n = (budget / size).max(4);

        let (copy_bps, copy_copied) = run_mode(false, size, n);
        let (zc_bps, zc_copied) = run_mode(true, size, n);
        let copy_per_get = copy_copied / n as u64;
        let zc_per_get = zc_copied / n as u64;
        for (mode, bps, per_get) in [
            ("copy", copy_bps, copy_per_get),
            ("zerocopy", zc_bps, zc_per_get),
        ] {
            bench.row(format!(
                "{mode},{size},{n},{:.2},{per_get}",
                bps / 1e9
            ));
        }
        worst_ratio = worst_ratio.min(zc_bps / copy_bps);

        // Counter gates are deterministic, so assert rather than
        // compare. The event-loop ingress (Linux default) is the
        // zero-copy egress; elsewhere the threaded fallback flat-encodes
        // every reply and the O(header) bound does not apply.
        if cfg!(target_os = "linux") {
            assert!(
                zc_per_get <= 4096,
                "zero-copy GET of {size}B copied {zc_per_get}B \
                 (want O(header))"
            );
            assert!(
                copy_per_get >= size as u64,
                "copy-mode GET of {size}B only counted {copy_per_get}B \
                 copied"
            );
        }
    }

    bench.note(&format!(
        "peak rss {} (process high-water across both modes)",
        fmt_bytes(peak_rss_bytes() as usize)
    ));
    bench.compare(
        "zero-copy GET throughput vs copy baseline (>=1 MiB values)",
        ">=1.5x",
        &format!("{worst_ratio:.2}x"),
        worst_ratio >= 1.5,
    );
    bench.finish();
}
