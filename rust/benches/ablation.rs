//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Future rendezvous mechanism** — server-side parking (`WaitGet`,
//!    what ProxyFutures uses on redis-sim) vs client-side polling (the
//!    generic connector fallback): set→resolve latency.
//! 2. **Connector choice** — memory vs TCP-KV vs file for a 1 MB proxy
//!    round-trip (the paper: "the exact threshold depends on the
//!    connector").
//! 3. **StoreExecutor auto-proxy threshold** — end-to-end task latency
//!    across payload sizes for thresholds {64 B, 1 kB, 64 kB, ∞}.

use std::sync::Arc;
use std::time::Duration;

use proxystore::benchlib::{fmt_bytes, fmt_secs, sample, Bench, Scale};
use proxystore::codec::{Bytes, Encode};
use proxystore::engine::{ClusterConfig, LocalCluster, StoreExecutor};
use proxystore::engine::TaskArg;
use proxystore::futures::ProxyFuture;
use proxystore::net::ServerBuilder;
use proxystore::metrics::Stats;
use proxystore::prelude::Store;
use proxystore::store::{Connector, FileConnector, TcpKvConnector};

fn main() {
    let scale = Scale::from_env();
    let samples = scale.pick(5, 15, 40);
    let mut bench = Bench::new("ablation", "experiment,variant,mean_s,p95_s");

    // ------------------------------------------------------------------
    // 1) Future rendezvous: parked WaitGet vs polling.
    // ------------------------------------------------------------------
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let parked_store = Store::new(
        "park",
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    );
    // Polling variant: file connector's default wait_get (poll+backoff).
    let dir = std::env::temp_dir().join(format!("pxs-abl-{}", std::process::id()));
    let polling_store = Store::new(
        "poll",
        Arc::new(FileConnector::new(dir.clone()).unwrap()),
    );

    for (label, store) in [("waitget-parked", &parked_store), ("polling", &polling_store)] {
        let xs = sample(3, samples, || {
            let fut: ProxyFuture<u64> = store.future();
            let p = fut.proxy();
            let setter = {
                let fut = fut.clone();
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(5));
                    fut.set_result(&7).unwrap();
                })
            };
            let v = *p.resolve().unwrap();
            setter.join().unwrap();
            store.evict(fut.key()).unwrap();
            assert_eq!(v, 7);
        });
        let s = Stats::from(&xs);
        bench.row(format!("future-rendezvous,{label},{:.6},{:.6}", s.mean, s.p95));
    }
    bench.note("both include the producer's fixed 5ms delay");

    // ------------------------------------------------------------------
    // 2) Connector choice for a 1MB proxy round-trip.
    // ------------------------------------------------------------------
    let mem_store = Store::memory("abl-mem");
    let tcp_store = Store::new(
        "abl-tcp",
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    );
    let file_store = Store::new(
        "abl-file",
        Arc::new(FileConnector::new(dir.join("conn")).unwrap()),
    );
    let payload = Bytes(vec![7u8; 1_000_000]);
    for (label, store) in [
        ("memory", &mem_store),
        ("tcp-kv", &tcp_store),
        ("file", &file_store),
    ] {
        let xs = sample(3, samples, || {
            let p = store.proxy(&payload).unwrap();
            let fresh: proxystore::proxy::Proxy<Bytes> =
                proxystore::proxy::Proxy::from_factory(p.factory().clone());
            let v = fresh.into_inner().unwrap();
            store.evict(p.key()).unwrap();
            assert_eq!(v.0.len(), 1_000_000);
        });
        let s = Stats::from(&xs);
        bench.row(format!("connector-1MB,{label},{:.6},{:.6}", s.mean, s.p95));
        println!(
            "  connector {label}: mean {} p95 {}",
            fmt_secs(s.mean),
            fmt_secs(s.p95)
        );
    }

    // ------------------------------------------------------------------
    // 3) StoreExecutor auto-proxy threshold sweep.
    // ------------------------------------------------------------------
    let sizes = [256usize, 4_096, 65_536, 1_048_576];
    for &threshold in &[64usize, 1_024, 65_536, usize::MAX] {
        let cluster = Arc::new(LocalCluster::new(ClusterConfig {
            workers: 2,
            ..Default::default()
        }));
        let executor = StoreExecutor::new(cluster, Store::memory("abl-exec"))
            .with_policy(proxystore::engine::executor_policy(threshold));
        for &size in &sizes {
            let data = Bytes(vec![1u8; size]);
            let xs = sample(2, samples, || {
                let arg = executor.make_arg(&data).unwrap();
                let fut = executor.submit::<u64>(
                    vec![arg],
                    Box::new(|_, args| {
                        let b: Bytes = args[0].get()?;
                        Ok((b.0.len() as u64).to_bytes())
                    }),
                );
                assert_eq!(fut.result().unwrap() as usize, size);
            });
            let s = Stats::from(&xs);
            let tlabel = if threshold == usize::MAX {
                "inf".to_string()
            } else {
                fmt_bytes(threshold)
            };
            bench.row(format!(
                "exec-threshold-{},{}, {:.6},{:.6}",
                tlabel,
                fmt_bytes(size),
                s.mean,
                s.p95
            ));
        }
    }
    // Sanity: with threshold=inf everything inlines.
    {
        let cluster = Arc::new(LocalCluster::new(ClusterConfig::default()));
        let ex = StoreExecutor::new(cluster, Store::memory("abl-chk"))
            .with_policy(proxystore::engine::executor_policy(usize::MAX));
        let arg = ex.make_arg(&Bytes(vec![0; 100_000])).unwrap();
        assert!(matches!(arg, TaskArg::Value(_)));
    }

    std::fs::remove_dir_all(&dir).ok();
    bench.finish();
}
