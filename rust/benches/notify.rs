//! Watch vs poll wake latency: the acceptance bench for the event-driven
//! watch/notify plane.
//!
//! Single-waiter: a consumer parks on a key that a producer stores after
//! the waiter has settled; measured is put→wake. The poll path is the old
//! `wait_get` default (poll with exponential backoff to a 10 ms floor),
//! isolated by a wrapper that hides the native watch; the watch path is
//! the registry callback (memory) and the out-of-band `Notify` push over
//! a pipelined TCP connection. Acceptance bar: watch wake latency beats
//! the poll path's backoff floor on both channels.
//!
//! Fan-out: 64 waiters parked on one key over ONE pipelined connection;
//! a single put must wake all of them (measured: put→last-wake span).

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::benchlib::{fmt_secs, Bench, Scale};
use proxystore::codec::Bytes;
use proxystore::kv::KvClient;
use proxystore::net::ServerBuilder;
use proxystore::store::{
    Blob, Connector, ConnectorDesc, MemoryConnector, TcpKvConnector,
};
use proxystore::Result;

/// Hides the wrapped channel's native watch so the default poll-bridge
/// (the pre-watch-plane behaviour of every sharded/elastic path) is
/// measurable against identical storage.
struct PollOnly(Arc<dyn Connector>);

impl Connector for PollOnly {
    fn desc(&self) -> ConnectorDesc {
        self.0.desc()
    }
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.0.put(key, data)
    }
    fn get(&self, key: &str) -> Result<Option<Blob>> {
        self.0.get(key)
    }
    fn evict(&self, key: &str) -> Result<()> {
        self.0.evict(key)
    }
    fn exists(&self, key: &str) -> Result<bool> {
        self.0.exists(key)
    }
    fn len(&self) -> Result<usize> {
        self.0.len()
    }
    // No watch/wait_get overrides: waits ride the default poll bridge.
}

/// put→wake latency for one parked waiter. `settle` lets the waiter arm
/// (and, on the poll path, lets the backoff ramp to its floor) before the
/// producer stores.
fn wake_latency(conn: &Arc<dyn Connector>, key: &str, settle: Duration) -> f64 {
    let c2 = conn.clone();
    let k2 = key.to_string();
    let waiter = std::thread::spawn(move || {
        let v = c2
            .wait_get(&k2, Some(Duration::from_secs(30)))
            .expect("wait_get")
            .expect("value must arrive");
        (Instant::now(), v.len())
    });
    std::thread::sleep(settle);
    let t_put = Instant::now();
    conn.put(key, vec![7u8; 64]).expect("put");
    let (woke, len) = waiter.join().expect("waiter");
    assert_eq!(len, 64);
    woke.saturating_duration_since(t_put).as_secs_f64()
}

fn avg_wake(conn: &Arc<dyn Connector>, tag: &str, rounds: usize) -> f64 {
    let settle = Duration::from_millis(60);
    let total: f64 = (0..rounds)
        .map(|i| wake_latency(conn, &format!("wake-{tag}-{i}"), settle))
        .sum();
    total / rounds as f64
}

fn main() {
    let scale = Scale::from_env();
    let rounds = scale.pick(8, 20, 40);

    let mut bench = Bench::new("notify", "path,avg_wake_s,rounds");
    bench.note(&format!(
        "{rounds} rounds per path; waiter settled 60ms before the put"
    ));

    // Poll path: default backoff loop (floor 10ms) over a memory engine.
    let poll: Arc<dyn Connector> = Arc::new(PollOnly(MemoryConnector::new()));
    let poll_avg = avg_wake(&poll, "poll", rounds);
    bench.row(format!("poll-bridge,{poll_avg:.6},{rounds}"));

    // Watch paths: registry callback (memory) and Notify push (TCP).
    let mem: Arc<dyn Connector> = MemoryConnector::new();
    let mem_avg = avg_wake(&mem, "mem", rounds);
    bench.row(format!("watch-memory,{mem_avg:.6},{rounds}"));

    let server = ServerBuilder::new().spawn_kv().expect("kv server");
    let tcp: Arc<dyn Connector> =
        Arc::new(TcpKvConnector::connect(server.addr).expect("connect"));
    let tcp_avg = avg_wake(&tcp, "tcp", rounds);
    bench.row(format!("watch-tcp,{tcp_avg:.6},{rounds}"));

    // Fan-out: 64 waiters on one key over one pipelined connection.
    let client = Arc::new(KvClient::connect(server.addr).expect("client"));
    let handles: Vec<_> = (0..64).map(|_| client.watch("fan-out")).collect();
    assert_eq!(client.watches_armed(), 64);
    std::thread::sleep(Duration::from_millis(20));
    let setter = KvClient::connect(server.addr).expect("setter");
    let t_put = Instant::now();
    setter.set("fan-out", Bytes(vec![1; 64])).expect("set");
    for h in &handles {
        assert_eq!(h.wait().expect("fan-out wake").len(), 64);
    }
    let span = t_put.elapsed().as_secs_f64();
    bench.row(format!("fanout-64-waiters,{span:.6},1"));
    bench.note(&format!(
        "single put woke all 64 parked waiters in {}",
        fmt_secs(span)
    ));

    let worst_watch = mem_avg.max(tcp_avg);
    bench.compare(
        "watch wake latency vs poll backoff floor",
        "< poll avg",
        &format!(
            "watch {} vs poll {}",
            fmt_secs(worst_watch),
            fmt_secs(poll_avg)
        ),
        worst_watch < poll_avg,
    );
    bench.finish();
}
