//! Shard fabric benchmark: aggregate put/get/mget throughput at 1/2/4/8
//! shards, plus failover latency when a replica backend dies.
//!
//! Each backend sits behind an uncontended throttled link (fixed latency +
//! bandwidth), so the single-channel bottleneck is physically present and
//! the fabric's win — batched ops fan out to all shards in parallel — is
//! measured, not assumed. The acceptance bar: >= 2x aggregate mget
//! throughput at 4 shards vs 1.

use std::sync::Arc;
use std::time::{Duration, Instant};

use proxystore::benchlib::{fmt_bytes, fmt_secs, sample, Bench, Scale};
use proxystore::codec::{Bytes, Encode};
use proxystore::prelude::Store;
use proxystore::shard::ShardedConnector;
use proxystore::store::{Connector, MemoryConnector, ThrottledConnector};
use proxystore::testing::fail::FlakyConnector;

const LINK_LATENCY: Duration = Duration::from_micros(200);
const LINK_BW: f64 = 2.0e8; // 200 MB/s per backend

fn backend() -> Arc<dyn Connector> {
    ThrottledConnector::wrap(MemoryConnector::new(), LINK_LATENCY, LINK_BW)
}

fn fabric(shards: usize, replicas: usize) -> Arc<ShardedConnector> {
    Arc::new(
        ShardedConnector::new((0..shards).map(|_| backend()).collect(), replicas, 0)
            .expect("fabric"),
    )
}

fn main() {
    let scale = Scale::from_env();
    let samples = scale.pick(2, 5, 10);
    let n_keys = scale.pick(32, 64, 128);
    let size = scale.pick(64 * 1024, 256 * 1024, 1024 * 1024);

    let mut bench = Bench::new(
        "shard_fabric",
        "shards,mput_mb_s,get_loop_mb_s,mget_mb_s",
    );
    bench.note(&format!(
        "{n_keys} keys x {}, per-backend link {}us + {} MB/s",
        fmt_bytes(size),
        LINK_LATENCY.as_micros(),
        LINK_BW / 1e6
    ));

    let objs: Vec<Bytes> = (0..n_keys).map(|i| Bytes(vec![i as u8; size])).collect();
    let mb = (n_keys * size) as f64 / 1e6;
    let mut mget_by_shards: Vec<(usize, f64)> = Vec::new();

    for shards in [1usize, 2, 4, 8] {
        let router = fabric(shards, 1);
        let store = Store::new("bench", router.clone());

        // Fixed key set with store-encoded values: batched overwrites keep
        // resident memory bounded across samples, and `Store::get*` can
        // decode what the connector-level put stored.
        let items: Vec<(String, Vec<u8>)> = objs
            .iter()
            .enumerate()
            .map(|(i, obj)| (format!("bench-{i}"), obj.to_bytes()))
            .collect();
        let keys: Vec<String> = items.iter().map(|(k, _)| k.clone()).collect();

        // Batched put: one parallel fan-out per sample. The dataset clone
        // happens outside the timed region (put_many consumes its input),
        // so the column reports fabric throughput, not memcpy. First
        // sample doubles as warmup.
        let mut put = Vec::with_capacity(samples);
        for _ in 0..=samples {
            let batch = items.clone();
            let t0 = Instant::now();
            router.put_many(batch).expect("put_many");
            put.push(t0.elapsed().as_secs_f64());
        }
        put.remove(0);

        // Looped single-key gets: pays per-key link latency, no fan-out.
        let get_loop = sample(1, samples, || {
            for k in &keys {
                let b = store.get::<Bytes>(k).expect("get").expect("hit");
                std::hint::black_box(b.0.len());
            }
        });

        // Batched get: per-shard sub-batches run concurrently.
        let mget = sample(1, samples, || {
            let got: Vec<Option<Bytes>> = store.get_many(&keys).expect("mget");
            assert!(got.iter().all(|b| b.is_some()));
            std::hint::black_box(got.len())
        });

        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        let (put_s, loop_s, mget_s) = (mean(&put), mean(&get_loop), mean(&mget));
        mget_by_shards.push((shards, mb / mget_s));
        bench.row(format!(
            "{shards},{:.1},{:.1},{:.1}",
            mb / put_s,
            mb / loop_s,
            mb / mget_s
        ));

        // The memory-connector registry pins state process-wide: evict so
        // the next configuration starts from a clean slate.
        for k in &keys {
            router.evict(k).expect("evict");
        }
    }

    let tput = |n: usize| {
        mget_by_shards
            .iter()
            .find(|(s, _)| *s == n)
            .map(|(_, t)| *t)
            .unwrap_or(0.0)
    };
    let speedup = tput(4) / tput(1).max(1e-9);
    bench.compare(
        "mget throughput, 4 shards vs 1",
        ">= 2x",
        &format!("{speedup:.1}x"),
        speedup >= 2.0,
    );

    // ------------------------------------------------------------------
    // Failover latency: replicas=2, then kill one backend and measure the
    // read path before, during, and after the outage.
    // ------------------------------------------------------------------
    let shards = 4;
    let flaky: Vec<Arc<FlakyConnector>> =
        (0..shards).map(|_| FlakyConnector::wrap(backend())).collect();
    let router = Arc::new(
        ShardedConnector::new(
            flaky.iter().map(|f| f.clone() as Arc<dyn Connector>).collect(),
            2,
            0,
        )
        .expect("fabric"),
    );
    let store = Store::new("failover", router.clone());
    let keys = store.put_many(&objs).expect("put_many");
    // Keys whose primary is backend 0 exercise the fallback path.
    let victims: Vec<String> = keys
        .iter()
        .filter(|k| router.shard_for(k) == 0)
        .cloned()
        .collect();
    assert!(!victims.is_empty(), "no keys landed on shard 0");

    let probe = |label: &str| {
        let t0 = Instant::now();
        for k in &victims {
            let b = store.get::<Bytes>(k).expect("get").expect("hit");
            std::hint::black_box(b.0.len());
        }
        let per_key = t0.elapsed().as_secs_f64() / victims.len() as f64;
        println!("  failover {label}: {} / key", fmt_secs(per_key));
        per_key
    };

    let healthy = probe("healthy   ");
    flaky[0].set_down(true);
    let degraded = probe("primary down");
    flaky[0].set_down(false);
    probe("recovered ");
    bench.note(&format!(
        "failover: {} fallback reads, degraded/healthy = {:.2}x",
        router.fallback_reads(),
        degraded / healthy.max(1e-9)
    ));

    bench.finish();
}
