//! Fig 7 (paper §V-C): memory usage over a simulated map-reduce workflow
//! under four memory-management models.
//!
//! Expected shape: proxy-default grows monotonically (objects never
//! freed); ownership ≈ manual, both returning to baseline; no-proxy keeps
//! the store empty but runs slowest (data rides the engine).

use std::time::Duration;

use proxystore::apps::membench::{run, MemBenchConfig, MemMode};
use proxystore::benchlib::{fmt_bytes, fmt_secs, peak_rss_bytes, Bench, Scale};

fn main() {
    let scale = Scale::from_env();
    let cfg = MemBenchConfig {
        rounds: scale.pick(2, 4, 8),
        mappers: scale.pick(4, 8, 32),
        map_input: scale.pick(1_000_000, 10_000_000, 100_000_000),
        map_output: scale.pick(100_000, 1_000_000, 10_000_000),
        task_sleep: Duration::from_millis(scale.pick(50, 200, 500)),
        seed: 7,
    };

    let mut bench = Bench::new(
        "fig7_memory",
        "mode,t_s,rss_bytes,store_bytes",
    );
    bench.note(&format!("{cfg:?} (paper: 8 rounds × 32 mappers × 100MB)"));

    // Real process memory alongside the simulated store series: VmHWM is
    // a monotonic high-water mark, so the per-mode delta attributes any
    // growth to whichever run first pushed the ceiling up (0 = unknown
    // off Linux).
    let rss_baseline = peak_rss_bytes();
    let mut rss_prev = rss_baseline;
    let mut summary = Vec::new();
    for mode in MemMode::all() {
        let r = run(&cfg, mode).expect("fig7 run");
        for row in r.series.csv_rows() {
            bench.row(format!("{},{row}", mode.label()));
        }
        let rss_now = peak_rss_bytes();
        println!(
            "  [{}] peak={:.1}MB mean={:.1}MB final={:.1}MB makespan={} \
             peak_rss=+{}",
            mode.label(),
            r.series.peak_store() as f64 / 1e6,
            r.series.mean_store() / 1e6,
            r.series.final_store() as f64 / 1e6,
            fmt_secs(r.makespan),
            fmt_bytes(rss_now.saturating_sub(rss_prev) as usize)
        );
        rss_prev = rss_now;
        summary.push((mode, r));
    }
    bench.note(&format!(
        "process peak rss: {} baseline -> {} after sweep (map_input {})",
        fmt_bytes(rss_baseline as usize),
        fmt_bytes(rss_prev as usize),
        fmt_bytes(cfg.map_input)
    ));

    let get = |m: MemMode| summary.iter().find(|(mode, _)| *mode == m).unwrap();
    let (_, default) = get(MemMode::Default);
    let (_, manual) = get(MemMode::Manual);
    let (_, owned) = get(MemMode::Ownership);
    let (_, noproxy) = get(MemMode::NoProxy);

    bench.compare(
        "default-management memory growth",
        "increases over run, never freed",
        &format!("final {:.1}MB", default.series.final_store() as f64 / 1e6),
        default.series.final_store() > default.series.peak_store() / 2,
    );
    bench.compare(
        "ownership ≈ manual management",
        "identical traces",
        &format!(
            "mean {:.1}MB vs {:.1}MB",
            owned.series.mean_store() / 1e6,
            manual.series.mean_store() / 1e6
        ),
        {
            let ratio = owned.series.mean_store().max(1.0)
                / manual.series.mean_store().max(1.0);
            (0.5..2.0).contains(&ratio)
        },
    );
    bench.compare(
        "ownership frees everything",
        "returns to baseline",
        &format!("final {:.2}MB", owned.series.final_store() as f64 / 1e6),
        owned.series.final_store() < cfg.map_input as i64,
    );
    bench.compare(
        "no-proxy runtime penalty",
        "≈3× slower (Dask serialization)",
        &format!(
            "{} vs {} (proxy-ownership)",
            fmt_secs(noproxy.makespan),
            fmt_secs(owned.makespan)
        ),
        noproxy.makespan >= owned.makespan * 0.8,
    );
    bench.finish();
}
