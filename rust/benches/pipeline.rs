//! Pipelined vs per-call-blocking small-op throughput over the TCP KV
//! wire: the acceptance bench for the nonblocking submission redesign.
//!
//! Both modes drive the same server over one connection. "blocking" pays
//! one full round trip per op (submit + wait, the old client's contract);
//! "pipelined" keeps a window of ops in flight and waits for the window,
//! so the whole window shares one round-trip stream. Acceptance bar:
//! pipelined throughput >= 2x blocking at 64 in-flight ops for <= 1 KiB
//! payloads.

use proxystore::benchlib::{once, Bench, Scale};
use proxystore::codec::Bytes;
use proxystore::kv::KvClient;
use proxystore::net::ServerBuilder;
use proxystore::ops::Op;

const WINDOW: usize = 64;

/// ops/sec for a run of `n_ops` blocking round trips.
fn blocking_puts(client: &KvClient, n_ops: usize, payload: &[u8]) -> f64 {
    let (_, secs) = once(|| {
        for i in 0..n_ops {
            client
                .set(&format!("b-{i}"), Bytes(payload.to_vec()))
                .expect("blocking set");
        }
    });
    n_ops as f64 / secs
}

/// ops/sec with `WINDOW` ops in flight on the shared stream.
fn pipelined_puts(client: &KvClient, n_ops: usize, payload: &[u8]) -> f64 {
    let (_, secs) = once(|| {
        let mut handles = Vec::with_capacity(WINDOW);
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Put {
                key: format!("p-{i}"),
                data: payload.to_vec(),
            }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    h.wait()
                        .expect("pipelined put")
                        .into_unit()
                        .expect("unit completion");
                }
            }
        }
        for h in handles {
            h.wait()
                .expect("pipelined put")
                .into_unit()
                .expect("unit completion");
        }
    });
    n_ops as f64 / secs
}

/// ops/sec reading the keys back with a pipelined window.
fn pipelined_gets(client: &KvClient, n_ops: usize) -> f64 {
    let (_, secs) = once(|| {
        let mut handles = Vec::with_capacity(WINDOW);
        for i in 0..n_ops {
            handles.push(client.submit_op(Op::Get { key: format!("p-{i}") }));
            if handles.len() == WINDOW {
                for h in handles.drain(..) {
                    assert!(h
                        .wait()
                        .expect("pipelined get")
                        .into_value()
                        .expect("value completion")
                        .is_some());
                }
            }
        }
        for h in handles {
            h.wait().expect("pipelined get").into_value().expect("value");
        }
    });
    n_ops as f64 / secs
}

fn blocking_gets(client: &KvClient, n_ops: usize) -> f64 {
    let (_, secs) = once(|| {
        for i in 0..n_ops {
            assert!(client.get(&format!("b-{i}")).expect("get").is_some());
        }
    });
    n_ops as f64 / secs
}

fn main() {
    let scale = Scale::from_env();
    let n_ops = scale.pick(1024, 8192, 32768);
    let sizes: &[usize] = &[64, 1024];

    let server = ServerBuilder::new().spawn_kv().expect("kv server");
    let client = KvClient::connect(server.addr).expect("client");

    let mut bench = Bench::new(
        "pipeline",
        "op,payload_bytes,blocking_ops_s,pipelined_ops_s,speedup",
    );
    bench.note(&format!(
        "{n_ops} ops per mode, window {WINDOW}, one TCP connection"
    ));

    let mut worst_speedup = f64::INFINITY;
    for &size in sizes {
        let payload = vec![7u8; size];
        client.flush_all().expect("flush");

        // Warm both paths once so neither pays first-touch costs.
        blocking_puts(&client, WINDOW, &payload);
        pipelined_puts(&client, WINDOW, &payload);

        let b_put = blocking_puts(&client, n_ops, &payload);
        let p_put = pipelined_puts(&client, n_ops, &payload);
        let put_speedup = p_put / b_put;
        bench.row(format!(
            "put,{size},{b_put:.0},{p_put:.0},{put_speedup:.2}"
        ));

        let b_get = blocking_gets(&client, n_ops);
        let p_get = pipelined_gets(&client, n_ops);
        let get_speedup = p_get / b_get;
        bench.row(format!(
            "get,{size},{b_get:.0},{p_get:.0},{get_speedup:.2}"
        ));

        worst_speedup = worst_speedup.min(put_speedup).min(get_speedup);
    }

    bench.compare(
        "pipelined small-op throughput vs per-call blocking (64 in flight)",
        ">=2x",
        &format!("{worst_speedup:.2}x"),
        worst_speedup >= 2.0,
    );
    bench.finish();
}
