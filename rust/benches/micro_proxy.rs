//! Micro-benchmarks for the proxy substrate (paper §III):
//!
//! * proxy-vs-direct pass cost across object sizes — the paper reports
//!   proxies pay off above ~10 kB (connector- and engine-dependent);
//! * raw component costs: proxy create, proxy resolve, factory encode,
//!   KV server round-trip, future set/resolve.

use std::sync::Arc;
use std::time::Duration;

use proxystore::benchlib::{fmt_bytes, fmt_secs, sample, Bench, Scale};
use proxystore::codec::{Bytes, Decode, Encode};
use proxystore::net::ServerBuilder;
use proxystore::netsim::Link;
use proxystore::prelude::{Proxy, Store};
use proxystore::store::{TcpKvConnector, ThrottledConnector};

fn main() {
    let scale = Scale::from_env();
    let samples = scale.pick(5, 20, 50);

    let mut bench = Bench::new(
        "micro_proxy",
        "size_bytes,direct_s,proxy_s",
    );

    // Cost model (see DESIGN.md §5): a direct argument piggybacks on the
    // task message — no extra round-trip latency, but its bytes cross the
    // engine's two hops (client→scheduler→worker) at the client NIC rate
    // and get (de)serialized at each side. A proxied argument adds two
    // store round-trips (put at the producer, resolve at the consumer),
    // each paying the store's request latency, but moves the bulk over
    // the faster store fabric and skips the middle hop.
    let engine_link = Link::new(Duration::ZERO, 1.0e9).uncontended();
    let store = Store::new(
        "micro",
        ThrottledConnector::wrap(
            proxystore::store::MemoryConnector::new(),
            Duration::from_micros(25),
            5.0e9,
        ),
    );

    let sizes =
        [1_000, 10_000, 30_000, 100_000, 300_000, 1_000_000, 10_000_000];
    let mut crossover: Option<usize> = None;
    for &size in &sizes {
        let data = Bytes(vec![7u8; size]);

        // Direct: encode → link ×2 → decode (pass-by-value via engine).
        let direct = sample(2, samples, || {
            let wire = data.to_bytes();
            engine_link.transfer(wire.len());
            engine_link.transfer(wire.len());
            let back = Bytes::from_bytes(&wire).unwrap();
            std::hint::black_box(back.0.len())
        });

        // Proxy: create (store put) → ship factory ×2 → resolve at worker.
        let proxy = sample(2, samples, || {
            let p: Proxy<Bytes> = store.proxy(&data).unwrap();
            let wire = p.to_bytes();
            engine_link.transfer(wire.len());
            engine_link.transfer(wire.len());
            let p2: Proxy<Bytes> = Proxy::from_bytes(&wire).unwrap();
            let v = p2.into_inner().unwrap();
            store.evict(p.key()).unwrap();
            std::hint::black_box(v.0.len())
        });

        let (d, p) = (
            direct.iter().sum::<f64>() / direct.len() as f64,
            proxy.iter().sum::<f64>() / proxy.len() as f64,
        );
        bench.row(format!("{size},{d:.6},{p:.6}"));
        if p < d && crossover.is_none() {
            crossover = Some(size);
        }
    }
    bench.compare(
        "proxy pays off above",
        "~10 kB (deployment-dependent)",
        &crossover.map(fmt_bytes).unwrap_or_else(|| ">10MB".into()),
        crossover.map(|c| (10_000..=1_000_000).contains(&c)).unwrap_or(false),
    );

    // Component micro-costs.
    let small = Bytes(vec![1u8; 1000]);
    let create = sample(10, samples, || {
        let p = store.proxy(&small).unwrap();
        store.evict(p.key()).unwrap();
    });
    let s = proxystore::metrics::Stats::from(&create);
    println!("  proxy create+evict (1kB): mean {}", fmt_secs(s.mean));

    let p: Proxy<Bytes> = store.proxy(&small).unwrap();
    let resolve = sample(10, samples, || {
        let fresh: Proxy<Bytes> = Proxy::from_bytes(&p.to_bytes()).unwrap();
        std::hint::black_box(fresh.into_inner().unwrap().0.len())
    });
    let s = proxystore::metrics::Stats::from(&resolve);
    println!("  proxy resolve (1kB):      mean {}", fmt_secs(s.mean));

    let wire = sample(10, samples, || p.to_bytes().len());
    let s = proxystore::metrics::Stats::from(&wire);
    println!("  factory encode:           mean {}", fmt_secs(s.mean));

    // KV server round-trip over TCP.
    let server = ServerBuilder::new().spawn_kv().unwrap();
    let kv_store = Store::new(
        "micro-kv",
        Arc::new(TcpKvConnector::connect(server.addr).unwrap()),
    );
    let rtt = sample(10, samples, || {
        let key = kv_store.put(&small).unwrap();
        let _: Option<Bytes> = kv_store.get(&key).unwrap();
        kv_store.evict(&key).unwrap();
    });
    let s = proxystore::metrics::Stats::from(&rtt);
    println!("  kv TCP put+get+del (1kB): mean {}", fmt_secs(s.mean));

    // Future set → resolve latency.
    let fut_lat = sample(5, samples, || {
        let fut: proxystore::futures::ProxyFuture<u64> = store.future();
        let proxy = fut.proxy();
        fut.set_result(&1).unwrap();
        std::hint::black_box(*proxy.resolve().unwrap());
        store.evict(fut.key()).unwrap();
    });
    let s = proxystore::metrics::Stats::from(&fut_lat);
    println!("  future set+resolve:       mean {}", fmt_secs(s.mean));

    bench.finish();
}
